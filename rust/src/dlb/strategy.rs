//! Repartitioning strategies: *how* a rebalance produces the new
//! partition (DESIGN.md §7).
//!
//! The paper's pipeline always partitions from scratch and then glues
//! the result to an Oliker-Biswas remap; ParMETIS's `AdaptiveRepart`
//! lineage (unified repartitioning, URP) shows the real design space
//! spans scratch, multilevel adaptive, and diffusive repartitioning,
//! traded per event. This module names that choice; the mechanics live
//! in [`crate::partition::diffusion`],
//! [`crate::partition::graph::adaptive`] and
//! [`crate::dlb::RebalancePipeline`].

use crate::bail;
use crate::util::error::Result;
use std::fmt;

/// Which repartitioning path [`crate::dlb::RebalancePipeline::rebalance`]
/// takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepartitionStrategy {
    /// Today's path: full partition from scratch, then the
    /// Oliker-Biswas remap, then migration.
    Scratch,
    /// Diffusive incremental repartitioning: move load along the rank
    /// chain from the *current* distribution; migration volume is
    /// minimized by construction and no remap phase is needed.
    Diffusive,
    /// Multilevel k-way adaptive repartitioning (`AdaptiveRepart`):
    /// owner-seeded multilevel partition whose refinement trades edge
    /// cut against migration via `itr`; no remap phase is needed.
    Adaptive,
    /// URP-style per-event selection: price all three paths with the
    /// network model and run whichever is modeled cheapest.
    Auto,
}

impl RepartitionStrategy {
    /// Stable lowercase name (config/CLI spelling and report label).
    pub fn name(self) -> &'static str {
        match self {
            RepartitionStrategy::Scratch => "scratch",
            RepartitionStrategy::Diffusive => "diffusive",
            RepartitionStrategy::Adaptive => "adaptive",
            RepartitionStrategy::Auto => "auto",
        }
    }

    /// One-line description (the `phg-dlb methods` listing).
    pub fn description(self) -> &'static str {
        match self {
            RepartitionStrategy::Scratch => {
                "full partition from scratch, Oliker-Biswas remap, migrate (the paper's pipeline)"
            }
            RepartitionStrategy::Diffusive => {
                "incremental load flow along the rank chain; minimal migration, no remap"
            }
            RepartitionStrategy::Adaptive => {
                "multilevel k-way AdaptiveRepart from current owners; itr trades cut vs migration"
            }
            RepartitionStrategy::Auto => {
                "per-event URP-style pick of whichever path the network model prices cheapest"
            }
        }
    }

    /// Parse a config/CLI spec. Unknown specs error with the valid
    /// names.
    pub fn parse(spec: &str) -> Result<Self> {
        match spec {
            "scratch" => Ok(RepartitionStrategy::Scratch),
            "diffusive" => Ok(RepartitionStrategy::Diffusive),
            "adaptive" => Ok(RepartitionStrategy::Adaptive),
            "auto" => Ok(RepartitionStrategy::Auto),
            other => {
                bail!("unknown strategy {other:?}; valid: scratch, diffusive, adaptive, auto")
            }
        }
    }

    /// Every strategy, in documentation order.
    pub fn all() -> [RepartitionStrategy; 4] {
        [
            RepartitionStrategy::Scratch,
            RepartitionStrategy::Diffusive,
            RepartitionStrategy::Adaptive,
            RepartitionStrategy::Auto,
        ]
    }
}

impl fmt::Display for RepartitionStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_strategy() {
        for s in RepartitionStrategy::all() {
            assert_eq!(RepartitionStrategy::parse(s.name()).unwrap(), s);
            assert_eq!(format!("{s}"), s.name());
        }
    }

    #[test]
    fn unknown_spec_lists_valid_names() {
        let err = RepartitionStrategy::parse("urp").unwrap_err().to_string();
        assert!(err.contains("urp"), "{err}");
        for s in RepartitionStrategy::all() {
            assert!(err.contains(s.name()), "{err}");
        }
    }
}
