//! Trigger policies: *when* the DLB phase runs (DESIGN.md §6).
//!
//! The paper operates a single lambda threshold; Liu's thesis
//! (arXiv:1611.08266) compares threshold, cadence and cost-model
//! triggers and shows the choice changes the method verdict. Three
//! policies:
//!
//! * [`LambdaThreshold`] -- repartition when the load-imbalance factor
//!   exceeds a fixed threshold (the paper's policy);
//! * [`AfterAdaptation`] -- repartition every `interval` adaptations,
//!   regardless of lambda (the classic AMR cadence policy; interval 1
//!   is "always repartition");
//! * [`CostBenefit`] -- repartition only when the modeled cost of
//!   partition + remap + migration (priced via
//!   [`crate::dist::NetworkModel`], see
//!   [`crate::dlb::RebalancePipeline::estimate`]) is smaller than the
//!   modeled solve time recovered by restoring balance over a
//!   lookahead horizon of steps.

use crate::util::error::Result;
use crate::{bail, format_err};

/// A-priori modeled economics of rebalancing *now*, produced by
/// [`crate::dlb::RebalancePipeline::estimate`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostEstimate {
    /// Modeled one-off cost of partition + remap + migration (s).
    pub rebalance_cost: f64,
    /// Modeled solve time recovered per subsequent step if balance is
    /// restored: `solve_parallel_time * (lambda - 1)` (s).
    pub saving_per_step: f64,
}

impl CostEstimate {
    /// Steps until a rebalance pays for itself (infinite when nothing
    /// is saved per step).
    pub fn break_even_steps(&self) -> f64 {
        if self.saving_per_step > 0.0 {
            self.rebalance_cost / self.saving_per_step
        } else {
            f64::INFINITY
        }
    }
}

/// Everything a trigger policy may look at for one decision.
#[derive(Debug, Clone, Copy)]
pub struct TriggerContext {
    /// Adaptive step index.
    pub step: usize,
    /// Load-imbalance factor of the current distribution.
    pub lambda: f64,
    pub estimate: CostEstimate,
}

/// Decides, once per adaptive step, whether the rebalance pipeline
/// runs. `&mut self` so cadence policies can keep counters.
pub trait TriggerPolicy: Send + Sync {
    /// Display name including parameters (e.g. `lambda:1.20`).
    fn name(&self) -> String;
    fn should_rebalance(&mut self, ctx: &TriggerContext) -> bool;

    /// Whether this policy reads [`TriggerContext::estimate`]. Lets
    /// the driver skip the O(n) cost-model pass for policies that
    /// trigger on lambda or cadence alone.
    fn needs_estimate(&self) -> bool {
        false
    }

    /// Restore cadence state after a checkpoint restore: the policy
    /// has already been polled once per step for `steps` completed
    /// steps (the driver polls exactly once per adaptive step).
    /// Stateless policies ignore it.
    fn advance_to(&mut self, _steps: usize) {}
}

/// The paper's policy: fire when lambda exceeds a fixed threshold.
#[derive(Debug, Clone, Copy)]
pub struct LambdaThreshold {
    pub lambda: f64,
}

impl TriggerPolicy for LambdaThreshold {
    fn name(&self) -> String {
        format!("lambda:{:.2}", self.lambda)
    }

    fn should_rebalance(&mut self, ctx: &TriggerContext) -> bool {
        ctx.lambda > self.lambda
    }
}

/// Fire every `interval`-th adaptation, regardless of lambda.
#[derive(Debug, Clone, Copy)]
pub struct AfterAdaptation {
    pub interval: usize,
    seen: usize,
}

impl AfterAdaptation {
    pub fn new(interval: usize) -> Self {
        Self {
            interval: interval.max(1),
            seen: 0,
        }
    }
}

impl TriggerPolicy for AfterAdaptation {
    fn name(&self) -> String {
        format!("every:{}", self.interval)
    }

    fn should_rebalance(&mut self, _ctx: &TriggerContext) -> bool {
        self.seen += 1;
        self.seen % self.interval == 0
    }

    fn advance_to(&mut self, steps: usize) {
        self.seen = steps;
    }
}

/// Fire only when the modeled saving over the lookahead horizon beats
/// the modeled rebalance cost. Never fires on a balanced mesh: with
/// lambda = 1 the saving is zero and no positive cost is worth paying.
#[derive(Debug, Clone, Copy)]
pub struct CostBenefit {
    /// Lookahead horizon in adaptive steps over which the restored
    /// balance is assumed to persist.
    pub horizon: usize,
}

impl TriggerPolicy for CostBenefit {
    fn name(&self) -> String {
        format!("costbenefit:{}", self.horizon)
    }

    fn should_rebalance(&mut self, ctx: &TriggerContext) -> bool {
        ctx.lambda > 1.0 + 1e-9
            && ctx.estimate.saving_per_step * self.horizon as f64 > ctx.estimate.rebalance_cost
    }

    fn needs_estimate(&self) -> bool {
        true
    }
}

/// One registered trigger-policy kind: its spec syntax and a one-line
/// description (the `phg-dlb methods` listing).
pub struct TriggerSpec {
    /// Spec syntax accepted by [`trigger_by_name`].
    pub name: &'static str,
    pub description: &'static str,
}

/// Every trigger-policy kind, in documentation order.
pub const TRIGGERS: [TriggerSpec; 4] = [
    TriggerSpec {
        name: "lambda[:t]",
        description: "fire when the load-imbalance factor exceeds t (the paper's policy)",
    },
    TriggerSpec {
        name: "every[:n]",
        description: "fire every n-th adaptation regardless of imbalance (AMR cadence)",
    },
    TriggerSpec {
        name: "always",
        description: "fire on every adaptation (= every:1)",
    },
    TriggerSpec {
        name: "costbenefit[:h]",
        description: "fire when the modeled rebalance cost is repaid within h balanced steps",
    },
];

/// Instantiate a trigger policy from its config/CLI spec:
/// `lambda[:threshold]` (threshold defaults to `default_lambda`),
/// `every[:interval]`, `always` (= `every:1`), `costbenefit[:horizon]`.
pub fn trigger_by_name(spec: &str, default_lambda: f64) -> Result<Box<dyn TriggerPolicy>> {
    let (kind, param) = match spec.split_once(':') {
        Some((k, p)) => (k, Some(p)),
        None => (spec, None),
    };
    match kind {
        "lambda" => {
            let t = match param {
                Some(p) => p
                    .parse::<f64>()
                    .map_err(|_| format_err!("trigger {spec:?}: bad float threshold"))?,
                None => default_lambda,
            };
            Ok(Box::new(LambdaThreshold { lambda: t }))
        }
        "every" => {
            let n = match param {
                Some(p) => p
                    .parse::<usize>()
                    .map_err(|_| format_err!("trigger {spec:?}: bad integer interval"))?,
                None => 1,
            };
            Ok(Box::new(AfterAdaptation::new(n)))
        }
        "always" => Ok(Box::new(AfterAdaptation::new(1))),
        "costbenefit" => {
            let h = match param {
                Some(p) => p
                    .parse::<usize>()
                    .map_err(|_| format_err!("trigger {spec:?}: bad integer horizon"))?,
                None => 8,
            };
            Ok(Box::new(CostBenefit { horizon: h.max(1) }))
        }
        other => bail!(
            "unknown trigger policy {other:?}; valid: lambda[:threshold], \
             every[:interval], always, costbenefit[:horizon]"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(lambda: f64, cost: f64, saving: f64) -> TriggerContext {
        TriggerContext {
            step: 0,
            lambda,
            estimate: CostEstimate {
                rebalance_cost: cost,
                saving_per_step: saving,
            },
        }
    }

    #[test]
    fn lambda_threshold_matches_paper_policy() {
        let mut t = LambdaThreshold { lambda: 1.2 };
        assert!(!t.should_rebalance(&ctx(1.0, 0.0, 0.0)));
        assert!(!t.should_rebalance(&ctx(1.2, 0.0, 0.0)));
        assert!(t.should_rebalance(&ctx(1.21, 0.0, 0.0)));
    }

    #[test]
    fn after_adaptation_fires_on_cadence() {
        let mut t = AfterAdaptation::new(3);
        let fired: Vec<bool> = (0..7).map(|i| t.should_rebalance(&ctx(1.0 + i as f64, 0.0, 0.0))).collect();
        assert_eq!(fired, [false, false, true, false, false, true, false]);
        let mut always = AfterAdaptation::new(1);
        assert!(always.should_rebalance(&ctx(1.0, 0.0, 0.0)));
        assert!(always.should_rebalance(&ctx(1.0, 0.0, 0.0)));
    }

    #[test]
    fn advance_to_resumes_cadence_mid_cycle() {
        // a fresh policy advanced to k steps fires exactly like one
        // that was polled k times -- the checkpoint-restore contract
        for k in 0..7 {
            let mut polled = AfterAdaptation::new(3);
            for i in 0..k {
                polled.should_rebalance(&ctx(1.0 + i as f64, 0.0, 0.0));
            }
            let mut restored = AfterAdaptation::new(3);
            restored.advance_to(k);
            for i in 0..5 {
                let c = ctx(1.0 + i as f64, 0.0, 0.0);
                assert_eq!(polled.should_rebalance(&c), restored.should_rebalance(&c));
            }
        }
        // stateless policies are unaffected
        let mut l = LambdaThreshold { lambda: 1.2 };
        l.advance_to(17);
        assert!(l.should_rebalance(&ctx(1.3, 0.0, 0.0)));
    }

    #[test]
    fn cost_benefit_never_fires_when_balanced() {
        let mut t = CostBenefit { horizon: 100 };
        // even with a (bogus) positive saving, lambda = 1 means no fire
        assert!(!t.should_rebalance(&ctx(1.0, 0.0, 10.0)));
        // the honest balanced estimate: zero saving, positive cost
        assert!(!t.should_rebalance(&ctx(1.0, 1e-3, 0.0)));
    }

    #[test]
    fn cost_benefit_fires_exactly_above_break_even() {
        let mut t = CostBenefit { horizon: 4 };
        // saving 2e-3/step over 4 steps = 8e-3 vs cost 1e-2: keep
        assert!(!t.should_rebalance(&ctx(1.5, 1e-2, 2e-3)));
        // saving 3e-3/step over 4 steps = 1.2e-2 > 1e-2: fire
        assert!(t.should_rebalance(&ctx(1.5, 1e-2, 3e-3)));
        // horizon scales the verdict
        let mut t8 = CostBenefit { horizon: 8 };
        assert!(t8.should_rebalance(&ctx(1.5, 1e-2, 2e-3)));
    }

    #[test]
    fn break_even_steps() {
        let e = CostEstimate {
            rebalance_cost: 6.0,
            saving_per_step: 2.0,
        };
        assert_eq!(e.break_even_steps(), 3.0);
        assert_eq!(CostEstimate::default().break_even_steps(), f64::INFINITY);
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(trigger_by_name("lambda", 1.2).unwrap().name(), "lambda:1.20");
        assert_eq!(trigger_by_name("lambda:1.5", 1.2).unwrap().name(), "lambda:1.50");
        assert_eq!(trigger_by_name("every:4", 1.2).unwrap().name(), "every:4");
        assert_eq!(trigger_by_name("always", 1.2).unwrap().name(), "every:1");
        assert_eq!(
            trigger_by_name("costbenefit", 1.2).unwrap().name(),
            "costbenefit:8"
        );
        assert_eq!(
            trigger_by_name("costbenefit:3", 1.2).unwrap().name(),
            "costbenefit:3"
        );
        assert!(trigger_by_name("nope", 1.2).is_err());
        assert!(trigger_by_name("lambda:abc", 1.2).is_err());
        let err = trigger_by_name("frob", 1.2).unwrap_err().to_string();
        assert!(err.contains("costbenefit"), "{err}");
    }

    #[test]
    fn every_registered_trigger_spec_parses() {
        for spec in &TRIGGERS {
            let bare = spec.name.split('[').next().unwrap();
            assert!(trigger_by_name(bare, 1.2).is_ok(), "spec {bare} rejected");
            assert!(!spec.description.is_empty(), "{bare} undescribed");
        }
    }
}
