//! The unified rebalance pipeline, now strategy-aware (DESIGN.md §7,
//! §12): *scratch* (partition -> Oliker-Biswas remap -> migrate, the
//! paper's path), *diffusive* (incremental flow on the rank chain ->
//! migrate, no remap needed), *adaptive* (multilevel k-way
//! `AdaptiveRepart` from the current owners -> migrate, no remap
//! needed), or *auto* (URP-style per-event selection of whichever path
//! the network model prices cheapest).
//!
//! Before this module the coordinator hand-wired the phases inline;
//! the benches and examples each re-implemented the same sequence with
//! their own accounting. [`RebalancePipeline`] owns the composition
//! and [`RebalanceReport`] carries everything the paper's tables
//! aggregate: the strategy that ran, lambda before/after, TotalV/MaxV,
//! the kept-data fraction, per-phase measured wall and modeled network
//! time, and the full collective log.

use super::registry::Registry;
use super::strategy::RepartitionStrategy;
use super::trigger::CostEstimate;
use crate::dist::{migrate, Distribution, NetworkModel, ELEM_BYTES};
use crate::mesh::{ElemId, TetMesh};
use crate::obs::{self, Phase};
use crate::partition::diffusion::{chain_loads, solve_flow, DiffusionRepartitioner};
use crate::partition::graph::AdaptiveRepart;
use crate::partition::metrics::MigrationVolume;
use crate::partition::{CommOp, PartitionInput, Partitioner};
use crate::remap::{apply_map, oliker_biswas, SimilarityMatrix};
use crate::util::error::Result;
use crate::util::timer::Stopwatch;
use std::sync::atomic::{AtomicU64, Ordering};

/// What one full rebalance did, phase by phase.
#[derive(Debug, Clone)]
pub struct RebalanceReport {
    /// Partitioning method that produced the new subgrids
    /// (`"Diffusion"` when the diffusive path ran).
    pub method: String,
    /// Which repartitioning path actually ran (never `Auto`).
    pub strategy: RepartitionStrategy,
    /// Load-imbalance factor before / after migration.
    pub lambda_before: f64,
    pub lambda_after: f64,
    /// Per-rank weight totals before / after migration -- the full
    /// load profile the lambdas summarise, for per-rank inspection.
    pub rank_loads_before: Vec<f64>,
    pub rank_loads_after: Vec<f64>,
    /// Oliker-Biswas migration volumes (TotalV / MaxV / moved fraction).
    pub volume: MigrationVolume,
    /// Fraction of total weight the rebalance kept in place (for the
    /// diffusive path: 1 - moved fraction, since there is no remap).
    pub remap_kept_fraction: f64,
    /// Measured partitioner wall time (s).
    pub partition_wall: f64,
    /// Measured remap + migration wall time (s).
    pub migrate_wall: f64,
    /// Modeled network time of the partitioner's collectives (s).
    pub partition_comm_modeled: f64,
    /// Modeled network time of the remap's gather + broadcast (s);
    /// zero on the diffusive path, which needs no remap.
    pub remap_comm_modeled: f64,
    /// Modeled network time of the migration `AllToAllV` (s).
    pub migrate_modeled: f64,
    /// Every collective the SPMD formulation would have performed, in
    /// execution order (partition, then remap, then migration).
    pub comm_log: Vec<CommOp>,
}

impl RebalanceReport {
    /// Total modeled network time over all three phases (s).
    pub fn modeled_comm_total(&self) -> f64 {
        self.partition_comm_modeled + self.remap_comm_modeled + self.migrate_modeled
    }

    /// Full DLB time of this rebalance: measured wall + modeled
    /// network (the per-step quantity of the paper's Fig 3.3).
    pub fn dlb_time(&self) -> f64 {
        self.partition_wall + self.migrate_wall + self.modeled_comm_total()
    }
}

/// Partitioner + network model + distribution + strategy, composed
/// into the paper's partition -> remap -> migrate sequence or its
/// diffusive alternative.
pub struct RebalancePipeline {
    pub partitioner: Box<dyn Partitioner>,
    pub net: NetworkModel,
    pub dist: Distribution,
    /// Which path [`RebalancePipeline::rebalance`] takes; `Auto`
    /// resolves per event via [`RebalancePipeline::resolve_strategy`].
    pub strategy: RepartitionStrategy,
    /// The diffusive repartitioner the `Diffusive`/`Auto` paths run
    /// (its sweep bound is the quality-vs-cost knob).
    pub diffusion: DiffusionRepartitioner,
    /// The multilevel adaptive repartitioner the `Adaptive`/`Auto`
    /// paths run (its `itr` is the cut-vs-migration knob).
    pub adaptive: AdaptiveRepart,
    /// EWMA of the measured `AdaptiveRepart` wall (f64 bits; 0 =
    /// unset). Atomic so rebalances keep their `&self` signatures.
    adaptive_wall_ewma: AtomicU64,
}

impl RebalancePipeline {
    pub fn new(partitioner: Box<dyn Partitioner>, net: NetworkModel, dist: Distribution) -> Self {
        assert_eq!(net.nparts, dist.nparts, "network/distribution disagree");
        Self {
            partitioner,
            net,
            dist,
            strategy: RepartitionStrategy::Scratch,
            diffusion: DiffusionRepartitioner::new(),
            adaptive: AdaptiveRepart::parmetis_like(),
            adaptive_wall_ewma: AtomicU64::new(0),
        }
    }

    /// Measured-wall EWMA of the adaptive repartitioner, once at least
    /// one adaptive rebalance has run (the `Auto` estimate falls back
    /// to the driver's scratch wall estimate before that).
    pub fn adaptive_wall_estimate(&self) -> Option<f64> {
        let bits = self.adaptive_wall_ewma.load(Ordering::Relaxed);
        (bits != 0).then(|| f64::from_bits(bits))
    }

    /// Restore a checkpointed adaptive-wall EWMA (`None` clears it to
    /// the cold-start state). Part of the driver checkpoint surface
    /// (DESIGN.md §13): without this, `Auto`'s three-way argmin would
    /// restart cold on every resume.
    pub fn restore_adaptive_wall_estimate(&self, estimate: Option<f64>) {
        let bits = estimate.map_or(0, f64::to_bits);
        self.adaptive_wall_ewma.store(bits, Ordering::Relaxed);
    }

    fn note_adaptive_wall(&self, wall: f64) {
        let blended = match self.adaptive_wall_estimate() {
            Some(prev) => 0.5 * prev + 0.5 * wall,
            None => wall,
        };
        self.adaptive_wall_ewma
            .store(blended.to_bits(), Ordering::Relaxed);
    }

    /// Convenience: method by registry name, InfiniBand-class network.
    pub fn from_method(name: &str, nparts: usize) -> Result<Self> {
        Ok(Self::new(
            Registry::create(name)?,
            NetworkModel::infiniband(nparts),
            Distribution::new(nparts),
        ))
    }

    /// Builder: set the repartitioning strategy.
    pub fn with_strategy(mut self, strategy: RepartitionStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Run the configured strategy: partition `leaves` under
    /// `weights`, place the result on the ranks already holding the
    /// data (remap for scratch; by construction for diffusive),
    /// migrate, and report. `Auto` resolves with the pure network
    /// model (no solve-time context); the driver passes its solve
    /// history through [`RebalancePipeline::resolve_strategy`] +
    /// [`RebalancePipeline::rebalance_as`] instead.
    pub fn rebalance(
        &self,
        mesh: &mut TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
    ) -> RebalanceReport {
        let strategy = self.resolve_strategy(mesh, leaves, weights, 0.0, 0.0);
        self.rebalance_as(strategy, mesh, leaves, weights)
    }

    /// Run one *concrete* strategy (`Auto` is resolved first).
    pub fn rebalance_as(
        &self,
        strategy: RepartitionStrategy,
        mesh: &mut TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
    ) -> RebalanceReport {
        match strategy {
            RepartitionStrategy::Scratch => self.rebalance_scratch(mesh, leaves, weights),
            RepartitionStrategy::Diffusive => self.rebalance_diffusive(mesh, leaves, weights),
            RepartitionStrategy::Adaptive => self.rebalance_adaptive(mesh, leaves, weights),
            RepartitionStrategy::Auto => {
                let s = self.resolve_strategy(mesh, leaves, weights, 0.0, 0.0);
                debug_assert_ne!(s, RepartitionStrategy::Auto);
                self.rebalance_as(s, mesh, leaves, weights)
            }
        }
    }

    /// The paper's path: scratch partition -> Oliker-Biswas remap ->
    /// migrate.
    fn rebalance_scratch(
        &self,
        mesh: &mut TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
    ) -> RebalanceReport {
        let nparts = self.dist.nparts;
        let rank_loads_before = self.dist.rank_loads(mesh, leaves, weights);
        let lambda_before = crate::util::stats::imbalance(&rank_loads_before);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let input = PartitionInput::from_mesh(mesh, leaves, weights, &owners, nparts);

        let sw = Stopwatch::start();
        let result = {
            let _sp = obs::driver_span(Phase::Partition);
            self.partitioner.partition(&input)
        };
        let partition_wall = sw.elapsed();
        let mut parts = result.parts;
        let mut comm_log = result.comm;
        let partition_comm_modeled = self.net.sequence_time(&comm_log);

        let sw = Stopwatch::start();
        let remap = {
            let _sp = obs::driver_span(Phase::Remap);
            let sim = SimilarityMatrix::build(&owners, &parts, weights, nparts, nparts);
            let remap = oliker_biswas(&sim);
            apply_map(&mut parts, &remap.map);
            remap
        };
        let remap_comm_modeled = self.net.sequence_time(&remap.comm);
        let total_w: f64 = weights.iter().sum();
        let remap_kept_fraction = if total_w > 0.0 {
            remap.kept / total_w
        } else {
            1.0
        };
        comm_log.extend(remap.comm);

        let out = {
            let _sp = obs::driver_span(Phase::Migrate);
            migrate(mesh, leaves, &parts, weights, &self.net)
        };
        let migrate_wall = sw.elapsed();
        comm_log.extend(out.comm);

        let rank_loads_after = self.dist.rank_loads(mesh, leaves, weights);
        let lambda_after = crate::util::stats::imbalance(&rank_loads_after);
        let m = obs::metrics();
        m.counter_add("dlb.rebalances.scratch", 1);
        m.observe("dlb.partition_s", partition_wall);
        m.observe("dlb.migrate_s", migrate_wall);
        m.observe("dlb.total_v", out.volume.total_v);

        RebalanceReport {
            method: self.partitioner.name().to_string(),
            strategy: RepartitionStrategy::Scratch,
            lambda_before,
            lambda_after,
            rank_loads_before,
            rank_loads_after,
            volume: out.volume,
            remap_kept_fraction,
            partition_wall,
            migrate_wall,
            partition_comm_modeled,
            remap_comm_modeled,
            migrate_modeled: out.modeled_time,
            comm_log,
        }
    }

    /// The incremental path: diffusive flow on the rank chain ->
    /// migrate. No remap phase exists -- the flow already targets the
    /// ranks holding the data, so everything off-flow stays in place.
    fn rebalance_diffusive(
        &self,
        mesh: &mut TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
    ) -> RebalanceReport {
        let nparts = self.dist.nparts;
        let rank_loads_before = self.dist.rank_loads(mesh, leaves, weights);
        let lambda_before = crate::util::stats::imbalance(&rank_loads_before);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let input = PartitionInput::from_mesh(mesh, leaves, weights, &owners, nparts);

        let sw = Stopwatch::start();
        let result = {
            let _sp = obs::driver_span(Phase::Partition);
            self.diffusion.partition(&input)
        };
        let partition_wall = sw.elapsed();
        let parts = result.parts;
        let mut comm_log = result.comm;
        let partition_comm_modeled = self.net.sequence_time(&comm_log);

        let sw = Stopwatch::start();
        let out = {
            let _sp = obs::driver_span(Phase::Migrate);
            migrate(mesh, leaves, &parts, weights, &self.net)
        };
        let migrate_wall = sw.elapsed();
        comm_log.extend(out.comm);

        let rank_loads_after = self.dist.rank_loads(mesh, leaves, weights);
        let lambda_after = crate::util::stats::imbalance(&rank_loads_after);
        let m = obs::metrics();
        m.counter_add("dlb.rebalances.diffusive", 1);
        m.observe("dlb.partition_s", partition_wall);
        m.observe("dlb.migrate_s", migrate_wall);
        m.observe("dlb.total_v", out.volume.total_v);

        RebalanceReport {
            method: self.diffusion.name().to_string(),
            strategy: RepartitionStrategy::Diffusive,
            lambda_before,
            lambda_after,
            rank_loads_before,
            rank_loads_after,
            remap_kept_fraction: 1.0 - out.volume.moved_fraction,
            volume: out.volume,
            partition_wall,
            migrate_wall,
            partition_comm_modeled,
            remap_comm_modeled: 0.0,
            migrate_modeled: out.modeled_time,
            comm_log,
        }
    }

    /// The multilevel adaptive path: owner-seeded `AdaptiveRepart` ->
    /// migrate. Like the diffusive path there is no remap phase -- the
    /// partition is grown *from* the current owners, so part labels
    /// already coincide with the ranks holding the data.
    fn rebalance_adaptive(
        &self,
        mesh: &mut TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
    ) -> RebalanceReport {
        let nparts = self.dist.nparts;
        let rank_loads_before = self.dist.rank_loads(mesh, leaves, weights);
        let lambda_before = crate::util::stats::imbalance(&rank_loads_before);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let input = PartitionInput::from_mesh(mesh, leaves, weights, &owners, nparts);

        let sw = Stopwatch::start();
        let result = {
            let _sp = obs::driver_span(Phase::Partition);
            self.adaptive.partition(&input)
        };
        let partition_wall = sw.elapsed();
        self.note_adaptive_wall(partition_wall);
        let parts = result.parts;
        let mut comm_log = result.comm;
        let partition_comm_modeled = self.net.sequence_time(&comm_log);

        let sw = Stopwatch::start();
        let out = {
            let _sp = obs::driver_span(Phase::Migrate);
            migrate(mesh, leaves, &parts, weights, &self.net)
        };
        let migrate_wall = sw.elapsed();
        comm_log.extend(out.comm);

        let rank_loads_after = self.dist.rank_loads(mesh, leaves, weights);
        let lambda_after = crate::util::stats::imbalance(&rank_loads_after);
        let m = obs::metrics();
        m.counter_add("dlb.rebalances.adaptive", 1);
        m.observe("dlb.partition_s", partition_wall);
        m.observe("dlb.migrate_s", migrate_wall);
        m.observe("dlb.total_v", out.volume.total_v);

        RebalanceReport {
            method: self.adaptive.name().to_string(),
            strategy: RepartitionStrategy::Adaptive,
            lambda_before,
            lambda_after,
            rank_loads_before,
            rank_loads_after,
            remap_kept_fraction: 1.0 - out.volume.moved_fraction,
            volume: out.volume,
            partition_wall,
            migrate_wall,
            partition_comm_modeled,
            remap_comm_modeled: 0.0,
            migrate_modeled: out.modeled_time,
            comm_log,
        }
    }

    /// A-priori economics of rebalancing *now* with the configured
    /// strategy (`Auto` prices all paths and reports the chosen one),
    /// for the [`super::CostBenefit`] trigger -- computed without
    /// running a partitioner.
    pub fn estimate(
        &self,
        mesh: &TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
        solve_parallel_time: f64,
        partition_wall_estimate: f64,
    ) -> CostEstimate {
        self.resolve_and_estimate(
            mesh,
            leaves,
            weights,
            solve_parallel_time,
            partition_wall_estimate,
        )
        .1
    }

    /// Modeled (cost, predicted lambda-after) of one concrete
    /// strategy.
    ///
    /// * **Scratch** -- saving: local solve compute on the bottleneck
    ///   rank costs `lambda x` the balanced mean (DESIGN.md §3), so
    ///   restoring balance recovers `solve_parallel_time * (lambda -
    ///   1)` per step. Cost: the measured-wall estimate of the
    ///   partitioner (EWMA fed by the driver; 0 until the first
    ///   rebalance) plus the modeled collectives of a Scan-class
    ///   partitioner, the remap's gather + broadcast, and an
    ///   `AllToAllV` moving exactly the excess weight above the
    ///   per-rank mean.
    /// * **Diffusive** -- the flow system is actually solved (O(p)
    ///   sweeps): cost is one `Allreduce` of the rank loads plus an
    ///   `AllToAllV` carrying the flow volume; the predicted lambda is
    ///   what the bounded sweeps leave behind, so the saving honestly
    ///   degrades when the sweep budget cannot even out a severe
    ///   front.
    /// * **Adaptive** -- honest modeled estimate without running the
    ///   multilevel machinery: predicted TotalV from a *generously
    ///   budgeted* coarse-level flow solved to the refiner's own
    ///   balance tolerance (refinement balances to `1 + epsilon`, so
    ///   the predicted lambda is `~1 + epsilon`, never the flow's
    ///   sweep-starved residual), priced as the per-level refinement
    ///   collectives plus a flow-sized `AllToAllV`; the wall charge is
    ///   the measured adaptive EWMA once one adaptive rebalance has
    ///   run, else the caller's scratch wall estimate (adaptive's
    ///   multilevel pass is the same order of work as scratch's).
    pub fn estimate_for(
        &self,
        strategy: RepartitionStrategy,
        mesh: &TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
        solve_parallel_time: f64,
        partition_wall_estimate: f64,
    ) -> (CostEstimate, f64) {
        let p = self.dist.nparts;
        let loads = self.dist.rank_loads(mesh, leaves, weights);
        let total: f64 = loads.iter().sum();
        if total <= 0.0 {
            return (CostEstimate::default(), 1.0);
        }
        let mean = total / p as f64;
        let lambda = loads.iter().cloned().fold(0.0f64, f64::max) / mean;

        match strategy {
            RepartitionStrategy::Scratch => {
                let excess: f64 = loads.iter().map(|&l| (l - mean).max(0.0)).sum();
                let max_excess = loads
                    .iter()
                    .map(|&l| (l - mean).max(0.0))
                    .fold(0.0f64, f64::max);
                let ops = [
                    CommOp::Scan { bytes: 8 },
                    CommOp::Gather { bytes: p * p * 8 },
                    CommOp::Bcast { bytes: p * 2 },
                    CommOp::AllToAllV {
                        total_bytes: (excess * ELEM_BYTES as f64).ceil() as usize,
                        max_msg: (max_excess * ELEM_BYTES as f64).ceil() as usize,
                    },
                ];
                (
                    CostEstimate {
                        rebalance_cost: partition_wall_estimate + self.net.sequence_time(&ops),
                        saving_per_step: solve_parallel_time * (lambda - 1.0).max(0.0),
                    },
                    1.0,
                )
            }
            RepartitionStrategy::Diffusive => {
                let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
                let (_, chain) = chain_loads(mesh, leaves, &owners, weights, p);
                let flow = solve_flow(&chain, self.diffusion.max_sweeps, self.diffusion.lambda_tol);
                let lambda_after = flow.lambda_after().max(1.0);
                let ops = [
                    CommOp::Allreduce { bytes: p * 8 },
                    CommOp::AllToAllV {
                        total_bytes: (flow.total_volume() * ELEM_BYTES as f64).ceil() as usize,
                        max_msg: (flow.max_edge() * ELEM_BYTES as f64).ceil() as usize,
                    },
                ];
                // the O(p) flow solve is negligible next to a scratch
                // partition pass, so no wall-time charge
                (
                    CostEstimate {
                        rebalance_cost: self.net.sequence_time(&ops),
                        saving_per_step: solve_parallel_time * (lambda - lambda_after).max(0.0),
                    },
                    lambda_after,
                )
            }
            RepartitionStrategy::Adaptive => {
                let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
                let (_, chain) = chain_loads(mesh, leaves, &owners, weights, p);
                // generous sweep budget, tolerance = the refiner's own
                // epsilon: the k-way refinement balances to 1+epsilon
                // regardless of how many diffusion sweeps *would* have
                // been needed, and its migration is flow-like (the
                // excess drains through part boundaries)
                let sweeps = (p * p * 8).max(1024);
                let flow = solve_flow(&chain, sweeps, self.adaptive.epsilon);
                let lambda_after = flow.lambda_after().max(1.0);
                let n = leaves.len().max(1);
                let levels = ((n as f64 / self.adaptive.coarsen_to as f64).ln()
                    / 0.6f64.ln())
                .abs()
                .ceil() as usize;
                let mut ops = vec![CommOp::Allreduce { bytes: p * 8 }];
                for _ in 0..levels.max(1) * self.adaptive.fm_passes.max(1) {
                    ops.push(CommOp::Allreduce { bytes: p * 8 });
                }
                ops.push(CommOp::AllToAllV {
                    total_bytes: (flow.total_volume() * ELEM_BYTES as f64).ceil() as usize,
                    max_msg: (flow.max_edge() * ELEM_BYTES as f64).ceil() as usize,
                });
                let wall = self
                    .adaptive_wall_estimate()
                    .unwrap_or(partition_wall_estimate);
                (
                    CostEstimate {
                        rebalance_cost: wall + self.net.sequence_time(&ops),
                        saving_per_step: solve_parallel_time * (lambda - lambda_after).max(0.0),
                    },
                    lambda_after,
                )
            }
            RepartitionStrategy::Auto => unreachable!("estimate_for needs a concrete strategy"),
        }
    }

    /// The `Auto` decision table: one row per concrete candidate in
    /// tie order (diffusive, adaptive, scratch), carrying the modeled
    /// estimate, the predicted post-rebalance lambda, and the URP
    /// objective `total = rebalance_cost + solve_parallel_time *
    /// max(lambda_after - 1, 0)`.
    ///
    /// [`RebalancePipeline::resolve_and_estimate`]'s `Auto` arm is the
    /// argmin over exactly this table (strict `<`, earlier row wins
    /// ties), so a flight-recorded table always agrees with the
    /// decision that was made from it.
    pub fn candidate_costs(
        &self,
        mesh: &TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
        solve_parallel_time: f64,
        partition_wall_estimate: f64,
    ) -> Vec<(RepartitionStrategy, CostEstimate, f64, f64)> {
        [
            RepartitionStrategy::Diffusive,
            RepartitionStrategy::Adaptive,
            RepartitionStrategy::Scratch,
        ]
        .into_iter()
        .map(|s| {
            let (est, lambda_after) = self.estimate_for(
                s,
                mesh,
                leaves,
                weights,
                solve_parallel_time,
                partition_wall_estimate,
            );
            let total =
                est.rebalance_cost + solve_parallel_time * (lambda_after - 1.0).max(0.0);
            (s, est, lambda_after, total)
        })
        .collect()
    }

    /// Resolve the pipeline's strategy for one rebalance event.
    /// Concrete strategies pass through; `Auto` prices all three paths
    /// URP-style -- rebalance cost plus the residual-imbalance solve
    /// penalty of the next step -- and picks the cheapest (ties go to
    /// the path that migrates less: diffusive, then adaptive, then
    /// scratch).
    pub fn resolve_strategy(
        &self,
        mesh: &TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
        solve_parallel_time: f64,
        partition_wall_estimate: f64,
    ) -> RepartitionStrategy {
        self.resolve_and_estimate(
            mesh,
            leaves,
            weights,
            solve_parallel_time,
            partition_wall_estimate,
        )
        .0
    }

    /// Resolve the strategy *and* return its cost estimate in one
    /// pass, so the driver's cost/benefit trigger and its subsequent
    /// rebalance do not re-run the O(n) load/flow analysis per step.
    pub fn resolve_and_estimate(
        &self,
        mesh: &TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
        solve_parallel_time: f64,
        partition_wall_estimate: f64,
    ) -> (RepartitionStrategy, CostEstimate) {
        match self.strategy {
            RepartitionStrategy::Scratch
            | RepartitionStrategy::Diffusive
            | RepartitionStrategy::Adaptive => {
                let (est, _) = self.estimate_for(
                    self.strategy,
                    mesh,
                    leaves,
                    weights,
                    solve_parallel_time,
                    partition_wall_estimate,
                );
                (self.strategy, est)
            }
            RepartitionStrategy::Auto => {
                // tie order = ascending migration: diffusive moves the
                // least, adaptive only what refinement chooses, scratch
                // relabels everything the remap cannot keep -- encoded
                // once, in candidate_costs
                let table = self.candidate_costs(
                    mesh,
                    leaves,
                    weights,
                    solve_parallel_time,
                    partition_wall_estimate,
                );
                let mut best: Option<(RepartitionStrategy, CostEstimate, f64)> = None;
                for &(s, est, _, total) in &table {
                    let better = match &best {
                        None => true,
                        Some((_, _, best_total)) => total < *best_total,
                    };
                    if better {
                        best = Some((s, est, total));
                    }
                }
                let (s, est, _) = best.expect("candidates is non-empty");
                (s, est)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;

    /// A mesh skewed by refining rank 0's block twice.
    fn skewed(nparts: usize) -> (TetMesh, Vec<ElemId>) {
        let mut mesh = generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        for _ in 0..2 {
            let marked: Vec<_> = mesh
                .leaves_unordered()
                .into_iter()
                .filter(|&id| mesh.elem(id).owner == 0)
                .collect();
            mesh.refine(&marked);
        }
        let leaves = mesh.leaves_unordered();
        (mesh, leaves)
    }

    #[test]
    fn rebalance_restores_lambda_and_reports_phases() {
        let (mut mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("PHG/HSFC", 4).unwrap();
        let rep = pipe.rebalance(&mut mesh, &leaves, &weights);
        assert_eq!(rep.method, "PHG/HSFC");
        assert_eq!(rep.strategy, RepartitionStrategy::Scratch);
        assert!(rep.lambda_before > 1.3, "skew missing: {}", rep.lambda_before);
        assert!(rep.lambda_after < 1.2, "lambda {}", rep.lambda_after);
        assert!(rep.lambda_after <= rep.lambda_before);
        assert!(rep.volume.total_v > 0.0);
        assert!(rep.partition_wall > 0.0);
        assert!(rep.partition_comm_modeled > 0.0);
        assert!(rep.remap_comm_modeled > 0.0);
        assert!(rep.migrate_modeled > 0.0);
        assert!(rep.dlb_time() >= rep.modeled_comm_total());
        assert!(!rep.comm_log.is_empty());
        assert!(rep.remap_kept_fraction > 0.0 && rep.remap_kept_fraction <= 1.0);
        // per-rank load profiles carry the full picture the lambdas
        // summarise, bitwise consistently
        assert_eq!(rep.rank_loads_before.len(), 4);
        assert_eq!(rep.rank_loads_after.len(), 4);
        assert_eq!(
            crate::util::stats::imbalance(&rep.rank_loads_before),
            rep.lambda_before
        );
        assert_eq!(
            crate::util::stats::imbalance(&rep.rank_loads_after),
            rep.lambda_after
        );
        // owners really were rewritten
        let lam = pipe.dist.imbalance(&mesh, &leaves, &weights);
        assert!((lam - rep.lambda_after).abs() < 1e-12);
    }

    #[test]
    fn diffusive_rebalance_runs_without_remap_phase() {
        let (mut mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("PHG/HSFC", 4)
            .unwrap()
            .with_strategy(RepartitionStrategy::Diffusive);
        let rep = pipe.rebalance(&mut mesh, &leaves, &weights);
        assert_eq!(rep.method, "Diffusion");
        assert_eq!(rep.strategy, RepartitionStrategy::Diffusive);
        assert!(rep.lambda_after < 1.1, "lambda {}", rep.lambda_after);
        assert_eq!(rep.remap_comm_modeled, 0.0, "diffusion has no remap");
        assert!(rep.volume.total_v > 0.0);
        assert!(
            (rep.remap_kept_fraction - (1.0 - rep.volume.moved_fraction)).abs() < 1e-12
        );
        // one Allreduce + one AllToAllV, nothing else
        assert!(rep
            .comm_log
            .iter()
            .all(|op| matches!(op, CommOp::Allreduce { .. } | CommOp::AllToAllV { .. })));
    }

    #[test]
    fn adaptive_rebalance_runs_without_remap_phase() {
        let (mut mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("PHG/HSFC", 4)
            .unwrap()
            .with_strategy(RepartitionStrategy::Adaptive);
        assert!(pipe.adaptive_wall_estimate().is_none());
        let rep = pipe.rebalance(&mut mesh, &leaves, &weights);
        assert_eq!(rep.method, "AdaptiveRepart");
        assert_eq!(rep.strategy, RepartitionStrategy::Adaptive);
        assert!(rep.lambda_after < 1.1, "lambda {}", rep.lambda_after);
        assert!(rep.lambda_after < rep.lambda_before);
        assert_eq!(rep.remap_comm_modeled, 0.0, "adaptive has no remap");
        assert!(rep.volume.total_v > 0.0);
        // owner-seeded: the rebalance must move less than a relabel of
        // everything would (rank 0 holds ~70% of the weight here, so
        // most of that excess has to travel regardless)
        assert!(rep.volume.moved_fraction < 0.95, "{}", rep.volume.moved_fraction);
        assert!(
            (rep.remap_kept_fraction - (1.0 - rep.volume.moved_fraction)).abs() < 1e-12
        );
        // the measured wall feeds the EWMA the Auto estimate uses
        let ewma = pipe.adaptive_wall_estimate().expect("EWMA set after a run");
        assert!(ewma > 0.0);
    }

    #[test]
    fn adaptive_estimate_is_honest_about_cost_and_lambda() {
        let (mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("PHG/HSFC", 4).unwrap();
        let (est, lambda_after) = pipe.estimate_for(
            RepartitionStrategy::Adaptive,
            &mesh,
            &leaves,
            &weights,
            1.0,
            1e-3,
        );
        // without an EWMA the wall charge falls back to the caller's
        // scratch estimate, plus the per-level refinement collectives
        assert!(est.rebalance_cost > 1e-3, "{}", est.rebalance_cost);
        // refinement balances to ~1 + epsilon: the prediction must not
        // claim perfection, nor claim a sweep-starved residual
        assert!(lambda_after >= 1.0 && lambda_after <= 1.0 + pipe.adaptive.epsilon + 0.02,
            "predicted lambda {lambda_after}");
        assert!(est.saving_per_step > 0.0);
    }

    #[test]
    fn estimate_is_zero_saving_when_balanced() {
        let mut mesh = generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        // 48 leaves over 4 ranks: exactly balanced under unit weights
        Distribution::new(4).assign_blocks(&mut mesh, &leaves);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("RTK", 4).unwrap();
        let est = pipe.estimate(&mesh, &leaves, &weights, 1.0, 0.0);
        assert_eq!(est.saving_per_step, 0.0);
        assert!(est.rebalance_cost > 0.0, "a rebalance is never free");
    }

    #[test]
    fn estimate_saving_scales_with_skew_and_solve_time() {
        let (mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("RTK", 4).unwrap();
        let est1 = pipe.estimate(&mesh, &leaves, &weights, 1.0, 0.0);
        assert!(est1.saving_per_step > 0.0);
        let est2 = pipe.estimate(&mesh, &leaves, &weights, 2.0, 0.0);
        assert!((est2.saving_per_step - 2.0 * est1.saving_per_step).abs() < 1e-12);
        // the wall estimate adds straight into the cost
        let est3 = pipe.estimate(&mesh, &leaves, &weights, 1.0, 0.5);
        assert!((est3.rebalance_cost - est1.rebalance_cost - 0.5).abs() < 1e-12);
    }

    #[test]
    fn diffusive_estimate_is_cheaper_on_local_skew() {
        // a single overloaded rank next to its underloaded neighbours:
        // the diffusive path prices one Allreduce + a flow-sized
        // AllToAllV against scratch's Scan+Gather+Bcast+AllToAllV and
        // must come out cheaper per event
        let (mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("PHG/HSFC", 4)
            .unwrap()
            .with_strategy(RepartitionStrategy::Auto);
        let (scratch, _) = pipe.estimate_for(
            RepartitionStrategy::Scratch,
            &mesh,
            &leaves,
            &weights,
            0.0,
            1e-3, // a realistic measured partitioner wall
        );
        let (diff, lambda_after) = pipe.estimate_for(
            RepartitionStrategy::Diffusive,
            &mesh,
            &leaves,
            &weights,
            0.0,
            1e-3,
        );
        assert!(
            diff.rebalance_cost < scratch.rebalance_cost,
            "diffusive {} !< scratch {}",
            diff.rebalance_cost,
            scratch.rebalance_cost
        );
        assert!(lambda_after < 1.05, "flow left lambda {lambda_after}");
        assert_eq!(
            pipe.resolve_strategy(&mesh, &leaves, &weights, 0.0, 1e-3),
            RepartitionStrategy::Diffusive
        );
    }

    #[test]
    fn auto_falls_back_to_scratch_when_sweep_budget_cannot_balance() {
        // starve the diffusion of sweeps on a multi-hop imbalance: the
        // residual-lambda penalty then prices the diffusive path out
        let (mesh, leaves) = skewed(8);
        let weights = vec![1.0f64; leaves.len()];
        let mut pipe = RebalancePipeline::from_method("PHG/HSFC", 8)
            .unwrap()
            .with_strategy(RepartitionStrategy::Auto);
        pipe.diffusion.max_sweeps = 1;
        // huge solve time: residual imbalance is expensive
        let chosen = pipe.resolve_strategy(&mesh, &leaves, &weights, 10.0, 1e-3);
        assert_eq!(chosen, RepartitionStrategy::Scratch);
        // with a generous sweep budget the flow balances (tight
        // tolerance, so the residual penalty vanishes) and diffusion
        // wins again
        pipe.diffusion.max_sweeps = 4096;
        pipe.diffusion.lambda_tol = 1e-6;
        let chosen = pipe.resolve_strategy(&mesh, &leaves, &weights, 10.0, 1e-3);
        assert_eq!(chosen, RepartitionStrategy::Diffusive);
    }

    #[test]
    fn candidate_costs_table_matches_estimate_for_and_argmin() {
        let (mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("PHG/HSFC", 4)
            .unwrap()
            .with_strategy(RepartitionStrategy::Auto);
        let table = pipe.candidate_costs(&mesh, &leaves, &weights, 5.0, 1e-3);
        assert_eq!(table.len(), 3);
        assert_eq!(table[0].0, RepartitionStrategy::Diffusive);
        assert_eq!(table[1].0, RepartitionStrategy::Adaptive);
        assert_eq!(table[2].0, RepartitionStrategy::Scratch);
        // every row is bitwise the independent estimate_for call, and
        // the total is the published URP objective
        for &(s, est, lambda_after, total) in &table {
            let (e2, l2) = pipe.estimate_for(s, &mesh, &leaves, &weights, 5.0, 1e-3);
            assert_eq!(est.rebalance_cost, e2.rebalance_cost);
            assert_eq!(est.saving_per_step, e2.saving_per_step);
            assert_eq!(lambda_after, l2);
            assert_eq!(
                total,
                est.rebalance_cost + 5.0 * (lambda_after - 1.0).max(0.0)
            );
        }
        // the Auto resolution is the argmin over exactly this table
        // (strict <, earlier row wins ties)
        let mut best = &table[0];
        for row in &table[1..] {
            if row.3 < best.3 {
                best = row;
            }
        }
        assert_eq!(
            pipe.resolve_strategy(&mesh, &leaves, &weights, 5.0, 1e-3),
            best.0
        );
    }
}
