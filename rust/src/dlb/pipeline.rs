//! The unified rebalance pipeline: partition -> Oliker-Biswas remap ->
//! migrate, as one call with one structured report.
//!
//! Before this module the coordinator hand-wired the three phases
//! inline; the benches and examples each re-implemented the same
//! sequence with their own accounting. [`RebalancePipeline`] owns the
//! composition and [`RebalanceReport`] carries everything the paper's
//! tables aggregate: lambda before/after, TotalV/MaxV, the kept-data
//! fraction, per-phase measured wall and modeled network time, and the
//! full collective log.

use super::registry::Registry;
use super::trigger::CostEstimate;
use crate::dist::{migrate, Distribution, NetworkModel, ELEM_BYTES};
use crate::mesh::{ElemId, TetMesh};
use crate::partition::metrics::MigrationVolume;
use crate::partition::{CommOp, PartitionInput, Partitioner};
use crate::remap::{apply_map, oliker_biswas, SimilarityMatrix};
use crate::util::timer::Stopwatch;
use anyhow::Result;

/// What one full rebalance did, phase by phase.
#[derive(Debug, Clone)]
pub struct RebalanceReport {
    /// Partitioning method that produced the new subgrids.
    pub method: String,
    /// Load-imbalance factor before / after migration.
    pub lambda_before: f64,
    pub lambda_after: f64,
    /// Oliker-Biswas migration volumes (TotalV / MaxV / moved fraction).
    pub volume: MigrationVolume,
    /// Fraction of total weight the remap kept in place.
    pub remap_kept_fraction: f64,
    /// Measured partitioner wall time (s).
    pub partition_wall: f64,
    /// Measured remap + migration wall time (s).
    pub migrate_wall: f64,
    /// Modeled network time of the partitioner's collectives (s).
    pub partition_comm_modeled: f64,
    /// Modeled network time of the remap's gather + broadcast (s).
    pub remap_comm_modeled: f64,
    /// Modeled network time of the migration `AllToAllV` (s).
    pub migrate_modeled: f64,
    /// Every collective the SPMD formulation would have performed, in
    /// execution order (partition, then remap, then migration).
    pub comm_log: Vec<CommOp>,
}

impl RebalanceReport {
    /// Total modeled network time over all three phases (s).
    pub fn modeled_comm_total(&self) -> f64 {
        self.partition_comm_modeled + self.remap_comm_modeled + self.migrate_modeled
    }

    /// Full DLB time of this rebalance: measured wall + modeled
    /// network (the per-step quantity of the paper's Fig 3.3).
    pub fn dlb_time(&self) -> f64 {
        self.partition_wall + self.migrate_wall + self.modeled_comm_total()
    }
}

/// Partitioner + network model + distribution, composed into the
/// paper's partition -> remap -> migrate sequence.
pub struct RebalancePipeline {
    pub partitioner: Box<dyn Partitioner>,
    pub net: NetworkModel,
    pub dist: Distribution,
}

impl RebalancePipeline {
    pub fn new(partitioner: Box<dyn Partitioner>, net: NetworkModel, dist: Distribution) -> Self {
        assert_eq!(net.nparts, dist.nparts, "network/distribution disagree");
        Self {
            partitioner,
            net,
            dist,
        }
    }

    /// Convenience: method by registry name, InfiniBand-class network.
    pub fn from_method(name: &str, nparts: usize) -> Result<Self> {
        Ok(Self::new(
            Registry::create(name)?,
            NetworkModel::infiniband(nparts),
            Distribution::new(nparts),
        ))
    }

    /// Run the full sequence: partition `leaves` under `weights`,
    /// remap the new subgrids onto the ranks already holding their
    /// data, migrate, and report.
    pub fn rebalance(
        &self,
        mesh: &mut TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
    ) -> RebalanceReport {
        let nparts = self.dist.nparts;
        let lambda_before = self.dist.imbalance(mesh, leaves, weights);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let input = PartitionInput::from_mesh(mesh, leaves, weights, &owners, nparts);

        let sw = Stopwatch::start();
        let result = self.partitioner.partition(&input);
        let partition_wall = sw.elapsed();
        let mut parts = result.parts;
        let mut comm_log = result.comm;
        let partition_comm_modeled = self.net.sequence_time(&comm_log);

        let sw = Stopwatch::start();
        let sim = SimilarityMatrix::build(&owners, &parts, weights, nparts, nparts);
        let remap = oliker_biswas(&sim);
        apply_map(&mut parts, &remap.map);
        let remap_comm_modeled = self.net.sequence_time(&remap.comm);
        let total_w: f64 = weights.iter().sum();
        let remap_kept_fraction = if total_w > 0.0 {
            remap.kept / total_w
        } else {
            1.0
        };
        comm_log.extend(remap.comm);

        let out = migrate(mesh, leaves, &parts, weights, &self.net);
        let migrate_wall = sw.elapsed();
        comm_log.extend(out.comm);

        RebalanceReport {
            method: self.partitioner.name().to_string(),
            lambda_before,
            lambda_after: self.dist.imbalance(mesh, leaves, weights),
            volume: out.volume,
            remap_kept_fraction,
            partition_wall,
            migrate_wall,
            partition_comm_modeled,
            remap_comm_modeled,
            migrate_modeled: out.modeled_time,
            comm_log,
        }
    }

    /// A-priori economics of rebalancing *now*, for the
    /// [`super::CostBenefit`] trigger -- computed without running the
    /// partitioner.
    ///
    /// * Saving: local solve compute on the bottleneck rank costs
    ///   `lambda x` the balanced mean (DESIGN.md §3), so restoring
    ///   balance recovers `solve_parallel_time * (lambda - 1)` per
    ///   step, where `solve_parallel_time` is the previous step's
    ///   SPMD-scaled solve time.
    /// * Cost: the measured-wall estimate of the partitioner (EWMA fed
    ///   by the driver; 0 until the first rebalance) plus the modeled
    ///   collectives of a Scan-class partitioner, the remap's
    ///   gather + broadcast, and an `AllToAllV` moving exactly the
    ///   excess weight above the per-rank mean.
    pub fn estimate(
        &self,
        mesh: &TetMesh,
        leaves: &[ElemId],
        weights: &[f64],
        solve_parallel_time: f64,
        partition_wall_estimate: f64,
    ) -> CostEstimate {
        let p = self.dist.nparts;
        let loads = self.dist.rank_loads(mesh, leaves, weights);
        let total: f64 = loads.iter().sum();
        if total <= 0.0 {
            return CostEstimate::default();
        }
        let mean = total / p as f64;
        let lambda = loads.iter().cloned().fold(0.0f64, f64::max) / mean;
        let saving_per_step = solve_parallel_time * (lambda - 1.0).max(0.0);

        let excess: f64 = loads.iter().map(|&l| (l - mean).max(0.0)).sum();
        let max_excess = loads
            .iter()
            .map(|&l| (l - mean).max(0.0))
            .fold(0.0f64, f64::max);
        let ops = [
            CommOp::Scan { bytes: 8 },
            CommOp::Gather { bytes: p * p * 8 },
            CommOp::Bcast { bytes: p * 2 },
            CommOp::AllToAllV {
                total_bytes: (excess * ELEM_BYTES as f64).ceil() as usize,
                max_msg: (max_excess * ELEM_BYTES as f64).ceil() as usize,
            },
        ];
        CostEstimate {
            rebalance_cost: partition_wall_estimate + self.net.sequence_time(&ops),
            saving_per_step,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;

    /// A mesh skewed by refining rank 0's block twice.
    fn skewed(nparts: usize) -> (TetMesh, Vec<ElemId>) {
        let mut mesh = generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        for _ in 0..2 {
            let marked: Vec<_> = mesh
                .leaves_unordered()
                .into_iter()
                .filter(|&id| mesh.elem(id).owner == 0)
                .collect();
            mesh.refine(&marked);
        }
        let leaves = mesh.leaves_unordered();
        (mesh, leaves)
    }

    #[test]
    fn rebalance_restores_lambda_and_reports_phases() {
        let (mut mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("PHG/HSFC", 4).unwrap();
        let rep = pipe.rebalance(&mut mesh, &leaves, &weights);
        assert_eq!(rep.method, "PHG/HSFC");
        assert!(rep.lambda_before > 1.3, "skew missing: {}", rep.lambda_before);
        assert!(rep.lambda_after < 1.2, "lambda {}", rep.lambda_after);
        assert!(rep.lambda_after <= rep.lambda_before);
        assert!(rep.volume.total_v > 0.0);
        assert!(rep.partition_wall > 0.0);
        assert!(rep.partition_comm_modeled > 0.0);
        assert!(rep.remap_comm_modeled > 0.0);
        assert!(rep.migrate_modeled > 0.0);
        assert!(rep.dlb_time() >= rep.modeled_comm_total());
        assert!(!rep.comm_log.is_empty());
        assert!(rep.remap_kept_fraction > 0.0 && rep.remap_kept_fraction <= 1.0);
        // owners really were rewritten
        let lam = pipe.dist.imbalance(&mesh, &leaves, &weights);
        assert!((lam - rep.lambda_after).abs() < 1e-12);
    }

    #[test]
    fn estimate_is_zero_saving_when_balanced() {
        let mut mesh = generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        // 48 leaves over 4 ranks: exactly balanced under unit weights
        Distribution::new(4).assign_blocks(&mut mesh, &leaves);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("RTK", 4).unwrap();
        let est = pipe.estimate(&mesh, &leaves, &weights, 1.0, 0.0);
        assert_eq!(est.saving_per_step, 0.0);
        assert!(est.rebalance_cost > 0.0, "a rebalance is never free");
    }

    #[test]
    fn estimate_saving_scales_with_skew_and_solve_time() {
        let (mesh, leaves) = skewed(4);
        let weights = vec![1.0f64; leaves.len()];
        let pipe = RebalancePipeline::from_method("RTK", 4).unwrap();
        let est1 = pipe.estimate(&mesh, &leaves, &weights, 1.0, 0.0);
        assert!(est1.saving_per_step > 0.0);
        let est2 = pipe.estimate(&mesh, &leaves, &weights, 2.0, 0.0);
        assert!((est2.saving_per_step - 2.0 * est1.saving_per_step).abs() < 1e-12);
        // the wall estimate adds straight into the cost
        let est3 = pipe.estimate(&mesh, &leaves, &weights, 1.0, 0.5);
        assert!((est3.rebalance_cost - est1.rebalance_cost - 0.5).abs() < 1e-12);
    }
}
