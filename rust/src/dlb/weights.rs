//! Element weight models: what "load" means to the DLB loop.
//!
//! The paper's experiments weight every element equally, but follow-up
//! work (Liu's thesis, arXiv:1611.08266; the particulate-flow DLB
//! study, arXiv:1811.12742) shows the method verdict can flip once
//! elements are weighted by what they actually cost. Three models:
//!
//! * [`Unit`] -- every leaf weighs 1 (the paper's setting);
//! * [`DofWeighted`] -- each leaf weighs its share of the global P1
//!   dof count (refined regions carry proportionally more dofs per
//!   element *neighbourhood*, which is what the solver iterates over);
//! * [`Measured`] -- m-AIA-style dynamic weights: per-element costs
//!   fed back from the timed assembly/solve phases, EWMA-smoothed,
//!   inherited through the refinement forest so fresh children start
//!   from their parent's observed cost.
//!
//! All models return weights normalized to mean 1.0, so lambda values
//! and migration volumes stay comparable across models.

use crate::bail;
use crate::mesh::{ElemId, TetMesh, NONE};
use crate::util::error::Result;
use crate::util::hash::FxHashSet;
use std::collections::BTreeMap;

/// Learned weight-model state in checkpointable form: the EWMA factor
/// plus the per-element cost entries sorted by `ElemId` (the canonical
/// order the snapshot stores them in). See DESIGN.md §13.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightState {
    pub alpha: f64,
    pub costs: Vec<(ElemId, f64)>,
}

/// A pluggable notion of per-element computational load.
pub trait WeightModel: Send + Sync {
    fn name(&self) -> &'static str;

    /// One weight per entry of `leaves`, normalized to mean 1.0.
    fn weights(&self, mesh: &TetMesh, leaves: &[ElemId]) -> Vec<f64>;

    /// Feed back measured per-element costs (seconds). Models that do
    /// not learn from runtime measurements ignore this.
    fn observe(&mut self, _mesh: &TetMesh, _leaves: &[ElemId], _costs: &[f64]) {}

    /// Whether [`WeightModel::observe`] does anything. Lets the driver
    /// skip the O(n) cost-apportionment pass for static models.
    fn learns(&self) -> bool {
        false
    }

    /// Export learned state for a checkpoint; `None` for stateless
    /// models (nothing is stored and nothing needs restoring).
    fn export_state(&self) -> Option<WeightState> {
        None
    }

    /// Restore state previously produced by
    /// [`WeightModel::export_state`]. Stateless models ignore it.
    fn import_state(&mut self, _state: &WeightState) {}
}

/// Scale `w` so its mean is 1.0 (no-op for empty or all-zero input).
fn normalize_mean_one(mut w: Vec<f64>) -> Vec<f64> {
    if w.is_empty() {
        return w;
    }
    let mean = w.iter().sum::<f64>() / w.len() as f64;
    if mean > 0.0 {
        for x in &mut w {
            *x /= mean;
        }
    }
    w
}

/// Per-leaf share of the global P1 dof count: each vertex contributes
/// `1 / valence` to every leaf touching it, so the shares sum to the
/// number of active vertices. Shared with the coordinator, which uses
/// the same apportionment to split measured solve time into the
/// per-element costs it feeds [`Measured`].
pub fn dof_shares(mesh: &TetMesh, leaves: &[ElemId]) -> Vec<f64> {
    let mut valence = vec![0u32; mesh.vertices.len()];
    for &id in leaves {
        for &v in &mesh.elem(id).verts {
            valence[v as usize] += 1;
        }
    }
    leaves
        .iter()
        .map(|&id| {
            mesh.elem(id)
                .verts
                .iter()
                .map(|&v| 1.0 / valence[v as usize] as f64)
                .sum()
        })
        .collect()
}

/// The paper's setting: every leaf weighs 1.
#[derive(Debug, Default, Clone, Copy)]
pub struct Unit;

impl WeightModel for Unit {
    fn name(&self) -> &'static str {
        "unit"
    }

    fn weights(&self, _mesh: &TetMesh, leaves: &[ElemId]) -> Vec<f64> {
        vec![1.0; leaves.len()]
    }
}

/// Weight = the leaf's share of the global dof count.
#[derive(Debug, Default, Clone, Copy)]
pub struct DofWeighted;

impl WeightModel for DofWeighted {
    fn name(&self) -> &'static str {
        "dof"
    }

    fn weights(&self, mesh: &TetMesh, leaves: &[ElemId]) -> Vec<f64> {
        normalize_mean_one(dof_shares(mesh, leaves))
    }
}

/// Runtime-measured per-element cost, EWMA-smoothed across steps.
///
/// Unobserved elements inherit the nearest observed ancestor's cost
/// (children are born on their parent's rank with their parent's cost
/// profile); elements with no observed ancestor get the mean observed
/// cost, so a cold start reproduces [`Unit`].
///
/// Costs live in a `BTreeMap` rather than a hash map on purpose: the
/// mean in [`Measured::weights`] is a float sum over the map's
/// iteration order, and resume-equivalence (DESIGN.md §13) needs that
/// order -- hence the sum's rounding -- to be a pure function of the
/// entries, not of the map's insertion history.
#[derive(Debug, Clone)]
pub struct Measured {
    /// EWMA smoothing factor in (0, 1]; 1.0 = keep only the latest.
    pub alpha: f64,
    cost: BTreeMap<ElemId, f64>,
}

impl Measured {
    pub fn new() -> Self {
        Self {
            alpha: 0.5,
            cost: BTreeMap::new(),
        }
    }

    /// Observed cost of `id` or of its nearest observed ancestor.
    fn ancestor_cost(&self, mesh: &TetMesh, id: ElemId) -> Option<f64> {
        let mut cur = id;
        loop {
            if let Some(&c) = self.cost.get(&cur) {
                return Some(c);
            }
            let parent = mesh.elem(cur).parent;
            if parent == NONE {
                return None;
            }
            cur = parent;
        }
    }
}

impl Default for Measured {
    fn default() -> Self {
        Self::new()
    }
}

impl WeightModel for Measured {
    fn name(&self) -> &'static str {
        "measured"
    }

    fn weights(&self, mesh: &TetMesh, leaves: &[ElemId]) -> Vec<f64> {
        let mean = if self.cost.is_empty() {
            1.0
        } else {
            self.cost.values().sum::<f64>() / self.cost.len() as f64
        };
        let w = leaves
            .iter()
            .map(|&id| self.ancestor_cost(mesh, id).unwrap_or(mean).max(0.0))
            .collect();
        normalize_mean_one(w)
    }

    fn observe(&mut self, mesh: &TetMesh, leaves: &[ElemId], costs: &[f64]) {
        assert_eq!(leaves.len(), costs.len());
        // Prune entries for elements that are neither current leaves
        // nor their ancestors: coarsened-away children would otherwise
        // linger forever and, worse, leak their cost onto unrelated new
        // elements once the mesh arena recycles their ElemId.
        let mut live: FxHashSet<ElemId> = FxHashSet::default();
        for &id in leaves {
            let mut cur = id;
            while live.insert(cur) {
                let parent = mesh.elem(cur).parent;
                if parent == NONE {
                    break;
                }
                cur = parent;
            }
        }
        self.cost.retain(|id, _| live.contains(id));
        for (&id, &c) in leaves.iter().zip(costs) {
            match self.cost.entry(id) {
                std::collections::btree_map::Entry::Occupied(mut e) => {
                    let v = e.get_mut();
                    *v = (1.0 - self.alpha) * *v + self.alpha * c;
                }
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(c);
                }
            }
        }
    }

    fn learns(&self) -> bool {
        true
    }

    fn export_state(&self) -> Option<WeightState> {
        Some(WeightState {
            alpha: self.alpha,
            costs: self.cost.iter().map(|(&id, &c)| (id, c)).collect(),
        })
    }

    fn import_state(&mut self, state: &WeightState) {
        self.alpha = state.alpha;
        self.cost = state.costs.iter().copied().collect();
    }
}

/// One registered weight model: its `--weights` name and a one-line
/// description (the `phg-dlb methods` listing).
pub struct WeightSpec {
    pub name: &'static str,
    pub description: &'static str,
}

/// Every weight model, in documentation order.
pub const WEIGHT_MODELS: [WeightSpec; 3] = [
    WeightSpec {
        name: "unit",
        description: "every leaf weighs 1 (the paper's setting)",
    },
    WeightSpec {
        name: "dof",
        description: "each leaf weighs its share of the global P1 dof count",
    },
    WeightSpec {
        name: "measured",
        description: "EWMA of measured per-element cost fed back from timed solves",
    },
];

/// Instantiate a weight model from its config/CLI spec.
pub fn weight_model_by_name(spec: &str) -> Result<Box<dyn WeightModel>> {
    match spec {
        "unit" => Ok(Box::new(Unit)),
        "dof" => Ok(Box::new(DofWeighted)),
        "measured" => Ok(Box::new(Measured::new())),
        other => bail!("unknown weight model {other:?}; valid: unit, dof, measured"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;

    #[test]
    fn unit_weights_are_all_one() {
        let mesh = generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        let w = Unit.weights(&mesh, &leaves);
        assert!(w.iter().all(|&x| x == 1.0));
        assert_eq!(w.len(), leaves.len());
    }

    #[test]
    fn dof_shares_partition_the_global_dof_count() {
        // sum over elements of the per-element dof share telescopes to
        // the number of active vertices: each vertex contributes
        // valence * (1/valence) = 1
        let mut mesh = generator::cube_mesh(2);
        for _ in 0..2 {
            let marked: Vec<_> = mesh
                .leaves_unordered()
                .into_iter()
                .filter(|&id| mesh.centroid(id).norm() < 0.5)
                .collect();
            assert!(!marked.is_empty());
            mesh.refine(&marked);
        }
        let leaves = mesh.leaves_unordered();
        let shares = dof_shares(&mesh, &leaves);
        let total: f64 = shares.iter().sum();
        assert!(
            (total - mesh.n_vertices() as f64).abs() < 1e-9,
            "shares sum {total} != {} vertices",
            mesh.n_vertices()
        );
        // the normalized model keeps mean 1 and is genuinely nonuniform
        let w = DofWeighted.weights(&mesh, &leaves);
        let mean = w.iter().sum::<f64>() / w.len() as f64;
        assert!((mean - 1.0).abs() < 1e-12, "not normalized: {mean}");
        let spread = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - w.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 1e-6, "dof weights degenerate to unit");
    }

    #[test]
    fn measured_uniform_timings_reproduce_unit() {
        let mesh = generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        let mut m = Measured::new();
        m.observe(&mesh, &leaves, &vec![3.7e-4; leaves.len()]);
        let w = m.weights(&mesh, &leaves);
        let unit = Unit.weights(&mesh, &leaves);
        for (a, b) in w.iter().zip(&unit) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn measured_cold_start_reproduces_unit() {
        let mesh = generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        let w = Measured::new().weights(&mesh, &leaves);
        assert!(w.iter().all(|&x| (x - 1.0).abs() < 1e-12));
    }

    #[test]
    fn measured_tracks_nonuniform_costs_and_ewma() {
        let mesh = generator::cube_mesh(1);
        let leaves = mesh.leaves_unordered();
        let n = leaves.len();
        let mut m = Measured::new();
        // first half twice as expensive as the second
        let costs: Vec<f64> = (0..n).map(|i| if i < n / 2 { 2.0 } else { 1.0 }).collect();
        m.observe(&mesh, &leaves, &costs);
        let w = m.weights(&mesh, &leaves);
        assert!(w[0] > w[n - 1], "{} !> {}", w[0], w[n - 1]);
        assert!((w[0] / w[n - 1] - 2.0).abs() < 1e-9);
        // repeated identical observations are a fixpoint of the EWMA
        m.observe(&mesh, &leaves, &costs);
        let w2 = m.weights(&mesh, &leaves);
        for (a, b) in w.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn measured_children_inherit_parent_cost() {
        let mut mesh = generator::cube_mesh(1);
        let leaves = mesh.leaves_unordered();
        let n = leaves.len();
        let mut m = Measured::new();
        let costs: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
        m.observe(&mesh, &leaves, &costs);
        let parent = leaves[n - 1];
        let [a, b] = mesh.bisect(parent);
        let leaves2 = mesh.leaves_unordered();
        let w = m.weights(&mesh, &leaves2);
        let at = |id: ElemId| w[leaves2.iter().position(|&x| x == id).unwrap()];
        assert!((at(a) - at(b)).abs() < 1e-12, "siblings differ");
        assert!(at(a) > at(leaves[0]), "inherited cost lost");
    }

    #[test]
    fn measured_prunes_stale_entries_on_observe() {
        let mut mesh = generator::cube_mesh(1);
        let roots = mesh.leaves_unordered();
        let mut m = Measured::new();
        m.observe(&mesh, &roots, &vec![1.0; roots.len()]);
        // refine everything and observe the children too
        mesh.refine(&roots);
        let fine = mesh.leaves_unordered();
        m.observe(&mesh, &fine, &vec![2.0; fine.len()]);
        // coarsen all the way back: the childrens' entries must be
        // dropped on the next observe, before their ElemIds can be
        // recycled for unrelated new elements
        let mut guard = 0;
        loop {
            let c = mesh.coarsen(&mesh.leaves_unordered());
            if c == 0 {
                break;
            }
            guard += 1;
            assert!(guard < 20);
        }
        let coarse = mesh.leaves_unordered();
        m.observe(&mesh, &coarse, &vec![3.0; coarse.len()]);
        assert_eq!(
            m.cost.len(),
            coarse.len(),
            "stale entries survived the prune"
        );
    }

    #[test]
    fn measured_state_roundtrips_through_export_import() {
        let mesh = generator::cube_mesh(1);
        let leaves = mesh.leaves_unordered();
        let mut m = Measured::new();
        let costs: Vec<f64> = (0..leaves.len()).map(|i| 0.1 + i as f64).collect();
        m.observe(&mesh, &leaves, &costs);
        let state = m.export_state().unwrap();
        assert_eq!(state.costs.len(), leaves.len());
        let mut fresh = Measured::new();
        fresh.import_state(&state);
        assert_eq!(fresh.export_state().unwrap(), state);
        let (a, b) = (m.weights(&mesh, &leaves), fresh.weights(&mesh, &leaves));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // stateless models export nothing
        assert!(Unit.export_state().is_none());
        assert!(DofWeighted.export_state().is_none());
    }

    #[test]
    fn model_lookup_by_name() {
        for name in ["unit", "dof", "measured"] {
            assert_eq!(weight_model_by_name(name).unwrap().name(), name);
        }
        let err = weight_model_by_name("banana").unwrap_err().to_string();
        assert!(err.contains("unit") && err.contains("measured"), "{err}");
    }

    #[test]
    fn every_registered_weight_model_resolves() {
        assert_eq!(WEIGHT_MODELS.len(), 3);
        for spec in &WEIGHT_MODELS {
            assert_eq!(weight_model_by_name(spec.name).unwrap().name(), spec.name);
            assert!(!spec.description.is_empty(), "{} undescribed", spec.name);
        }
    }
}
