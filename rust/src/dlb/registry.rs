//! The single method registry: every partitioning method reachable by
//! name lives in exactly one table.
//!
//! Before this module existed the crate carried three disagreeing
//! copies of the name -> partitioner mapping (`partition::paper_lineup`,
//! `coordinator::partitioner_by_name`, `coordinator::METHOD_NAMES`);
//! RIB and Mitchell-RT were reachable by name but missing from the
//! lineup. [`METHODS`] is now the only source of truth: the paper's
//! six-method lineup in Table-1 presentation order, followed by the
//! ablation-only methods (including the diffusive incremental
//! repartitioner that backs the `Diffusive`/`Auto` strategies).

use crate::partition::{
    diffusion::DiffusionRepartitioner, graph::AdaptiveRepart, graph::MultilevelGraph,
    mitchell::MitchellRefinementTree, rcb::Rcb, rib::Rib, rtk::RefinementTree,
    sfc::SfcPartitioner, MethodTraits, Partitioner,
};
use crate::util::error::Result;
use crate::{bail, format_err};

/// One registered method: its paper name, whether it belongs to the
/// §3 experiment lineup, a one-line description (the `phg-dlb methods`
/// listing), and its constructor.
pub struct MethodSpec {
    pub name: &'static str,
    /// In the paper's six-method comparison (Tables 1-3, Figs 3.2-3.5).
    pub in_lineup: bool,
    /// One-line description for listings and docs.
    pub description: &'static str,
    pub make: fn() -> Box<dyn Partitioner>,
}

impl MethodSpec {
    /// Capabilities and tunables of this method (constructs a default
    /// instance; [`MethodTraits`] is statically declared, so this is
    /// cheap and allocation-light).
    pub fn traits(&self) -> MethodTraits {
        (self.make)().traits()
    }
}

/// Every method, lineup first (Table-1 presentation order), then the
/// ablation-only extras.
pub const METHODS: [MethodSpec; 10] = [
    MethodSpec {
        name: "RCB",
        in_lineup: true,
        description: "recursive coordinate bisection (Zoltan-style geometric baseline)",
        make: || Box::new(Rcb::new()),
    },
    MethodSpec {
        name: "ParMETIS",
        in_lineup: true,
        description: "multilevel k-way partitioning of the dual graph (ParMETIS stand-in)",
        make: || Box::new(MultilevelGraph::parmetis_like()),
    },
    MethodSpec {
        name: "RTK",
        in_lineup: true,
        description: "refinement-tree partitioner, prefix-sum formulation (paper §2.1)",
        make: || Box::new(RefinementTree::new()),
    },
    MethodSpec {
        name: "MSFC",
        in_lineup: true,
        description: "Morton SFC with aspect-preserving normalization (paper §2.2)",
        make: || Box::new(SfcPartitioner::msfc()),
    },
    MethodSpec {
        name: "PHG/HSFC",
        in_lineup: true,
        description: "Hilbert SFC with PHG's aspect-preserving normalization (paper §2.2)",
        make: || Box::new(SfcPartitioner::phg_hsfc()),
    },
    MethodSpec {
        name: "Zoltan/HSFC",
        in_lineup: true,
        description: "Hilbert SFC with Zoltan's per-axis normalization (paper §2.2)",
        make: || Box::new(SfcPartitioner::zoltan_hsfc()),
    },
    MethodSpec {
        name: "Diffusion",
        in_lineup: false,
        description: "diffusive incremental repartitioning on the rank chain (DESIGN.md §7)",
        make: || Box::new(DiffusionRepartitioner::new()),
    },
    MethodSpec {
        name: "RIB",
        in_lineup: false,
        description: "recursive inertial bisection (geometric ablation baseline)",
        make: || Box::new(Rib::new()),
    },
    MethodSpec {
        name: "Mitchell-RT",
        in_lineup: false,
        description: "Mitchell's original refinement-tree bisection (§2.1 ablation)",
        make: || Box::new(MitchellRefinementTree::new()),
    },
    MethodSpec {
        name: "AdaptiveRepart",
        in_lineup: false,
        description: "multilevel k-way adaptive repartitioning, itr trades cut vs migration",
        make: || Box::new(AdaptiveRepart::parmetis_like()),
    },
];

/// Namespace for method lookup over [`METHODS`].
pub struct Registry;

impl Registry {
    /// Instantiate a method from a spec string: a paper name,
    /// optionally followed by `:key=val,...` tunable assignments (e.g.
    /// `AdaptiveRepart:itr=100,fm_passes=8`). Unknown names error with
    /// the full list of valid ones; unknown keys, unparseable values
    /// and out-of-range values error naming the method's valid
    /// tunables with their ranges and defaults.
    pub fn create(spec_str: &str) -> Result<Box<dyn Partitioner>> {
        let (name, params) = match spec_str.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (spec_str, None),
        };
        let spec = match METHODS.iter().find(|m| m.name == name) {
            Some(spec) => spec,
            None => bail!(
                "unknown method {name:?}; valid methods: {}",
                Self::names().join(", ")
            ),
        };
        let mut p = (spec.make)();
        let Some(params) = params else { return Ok(p) };

        let tunables = p.traits().tunables;
        let valid = || -> String {
            if tunables.is_empty() {
                format!("method {name} has no tunables")
            } else {
                format!(
                    "valid tunables for {name}: {}",
                    tunables
                        .iter()
                        .map(|t| format!(
                            "{} (range [{}, {}], default {})",
                            t.key, t.min, t.max, t.default
                        ))
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            }
        };
        for kv in params.split(',').filter(|s| !s.is_empty()) {
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| format_err!("malformed parameter {kv:?} (want key=val); {}", valid()))?;
            let t = tunables
                .iter()
                .find(|t| t.key == key)
                .ok_or_else(|| format_err!("unknown tunable {key:?} for method {name}; {}", valid()))?;
            let v: f64 = val
                .parse()
                .map_err(|_| format_err!("tunable {key}={val:?}: expected a number; {}", valid()))?;
            if !(t.min..=t.max).contains(&v) {
                bail!(
                    "tunable {key}={v} out of range [{}, {}]; {}",
                    t.min,
                    t.max,
                    valid()
                );
            }
            p.set_tunable(key, v)?;
        }
        Ok(p)
    }

    /// All registered method names, lineup first.
    pub fn names() -> Vec<&'static str> {
        METHODS.iter().map(|m| m.name).collect()
    }

    /// The paper's six-method lineup names, presentation order.
    pub fn paper_names() -> Vec<&'static str> {
        METHODS
            .iter()
            .filter(|m| m.in_lineup)
            .map(|m| m.name)
            .collect()
    }

    /// Instantiate the full paper lineup, presentation order.
    pub fn paper_lineup() -> Vec<Box<dyn Partitioner>> {
        METHODS
            .iter()
            .filter(|m| m.in_lineup)
            .map(|m| (m.make)())
            .collect()
    }

    /// Every spec in sorted (byte-order) name order: the deterministic
    /// listing that `phg-dlb methods` prints, so CI log diffs and docs
    /// stay stable across registry edits.
    pub fn sorted_specs() -> Vec<&'static MethodSpec> {
        let mut specs: Vec<&'static MethodSpec> = METHODS.iter().collect();
        specs.sort_by_key(|m| m.name);
        specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_knows_all_methods() {
        for spec in &METHODS {
            let p = Registry::create(spec.name).unwrap();
            assert_eq!(p.name(), spec.name, "registry name mismatch");
            assert!(!spec.description.is_empty(), "{} undescribed", spec.name);
        }
        assert!(Registry::create("RIB").is_ok());
        assert!(Registry::create("Mitchell-RT").is_ok());
        assert!(Registry::create("Diffusion").is_ok());
    }

    #[test]
    fn unknown_method_lists_valid_names() {
        let err = Registry::create("nope").unwrap_err().to_string();
        assert!(err.contains("nope"), "{err}");
        for name in Registry::names() {
            assert!(err.contains(name), "error does not list {name}: {err}");
        }
    }

    #[test]
    fn paper_lineup_has_six_methods_in_order() {
        assert_eq!(
            Registry::paper_names(),
            ["RCB", "ParMETIS", "RTK", "MSFC", "PHG/HSFC", "Zoltan/HSFC"]
        );
        let lineup = Registry::paper_lineup();
        assert_eq!(lineup.len(), 6);
        for (p, name) in lineup.iter().zip(Registry::paper_names()) {
            assert_eq!(p.name(), name);
        }
    }

    #[test]
    fn parameterized_specs_round_trip() {
        // well-formed spec strings construct
        assert!(Registry::create("AdaptiveRepart:itr=100,fm_passes=8").is_ok());
        assert!(Registry::create("Diffusion:max_sweeps=16").is_ok());
        assert!(Registry::create("ParMETIS:coarsen_to=128,epsilon=0.05").is_ok());
        // a bare name still works for every method
        for spec in &METHODS {
            assert!(Registry::create(spec.name).is_ok());
        }
    }

    #[test]
    fn parameter_errors_name_the_valid_tunables() {
        // unknown key: error lists the valid keys with ranges
        let err = Registry::create("AdaptiveRepart:bogus=1")
            .unwrap_err()
            .to_string();
        assert!(err.contains("bogus"), "{err}");
        for key in ["itr", "fm_passes", "coarsen_to", "epsilon"] {
            assert!(err.contains(key), "error does not list {key}: {err}");
        }
        assert!(err.contains("range"), "{err}");

        // out of range: error states the range
        let err = Registry::create("AdaptiveRepart:epsilon=5")
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");

        // not a number
        let err = Registry::create("AdaptiveRepart:itr=abc")
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected a number"), "{err}");

        // missing '='
        let err = Registry::create("AdaptiveRepart:itr")
            .unwrap_err()
            .to_string();
        assert!(err.contains("key=val"), "{err}");

        // tunable-less method
        let err = Registry::create("RCB:foo=1").unwrap_err().to_string();
        assert!(err.contains("no tunables"), "{err}");
    }

    #[test]
    fn sorted_specs_are_sorted_and_complete() {
        let specs = Registry::sorted_specs();
        assert_eq!(specs.len(), METHODS.len());
        for w in specs.windows(2) {
            assert!(w[0].name < w[1].name, "{} !< {}", w[0].name, w[1].name);
        }
    }
}
