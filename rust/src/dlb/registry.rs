//! The single method registry: every partitioning method reachable by
//! name lives in exactly one table.
//!
//! Before this module existed the crate carried three disagreeing
//! copies of the name -> partitioner mapping (`partition::paper_lineup`,
//! `coordinator::partitioner_by_name`, `coordinator::METHOD_NAMES`);
//! RIB and Mitchell-RT were reachable by name but missing from the
//! lineup. [`METHODS`] is now the only source of truth: the paper's
//! six-method lineup in Table-1 presentation order, followed by the
//! ablation-only methods (including the diffusive incremental
//! repartitioner that backs the `Diffusive`/`Auto` strategies).

use crate::bail;
use crate::partition::{
    diffusion::DiffusionRepartitioner, graph::MultilevelGraph, mitchell::MitchellRefinementTree,
    rcb::Rcb, rib::Rib, rtk::RefinementTree, sfc::SfcPartitioner, Partitioner,
};
use crate::util::error::Result;

/// One registered method: its paper name, whether it belongs to the
/// §3 experiment lineup, a one-line description (the `phg-dlb methods`
/// listing), and its constructor.
pub struct MethodSpec {
    pub name: &'static str,
    /// In the paper's six-method comparison (Tables 1-3, Figs 3.2-3.5).
    pub in_lineup: bool,
    /// One-line description for listings and docs.
    pub description: &'static str,
    pub make: fn() -> Box<dyn Partitioner>,
}

/// Every method, lineup first (Table-1 presentation order), then the
/// ablation-only extras.
pub const METHODS: [MethodSpec; 9] = [
    MethodSpec {
        name: "RCB",
        in_lineup: true,
        description: "recursive coordinate bisection (Zoltan-style geometric baseline)",
        make: || Box::new(Rcb::new()),
    },
    MethodSpec {
        name: "ParMETIS",
        in_lineup: true,
        description: "multilevel k-way partitioning of the dual graph (ParMETIS stand-in)",
        make: || Box::new(MultilevelGraph::parmetis_like()),
    },
    MethodSpec {
        name: "RTK",
        in_lineup: true,
        description: "refinement-tree partitioner, prefix-sum formulation (paper §2.1)",
        make: || Box::new(RefinementTree::new()),
    },
    MethodSpec {
        name: "MSFC",
        in_lineup: true,
        description: "Morton SFC with aspect-preserving normalization (paper §2.2)",
        make: || Box::new(SfcPartitioner::msfc()),
    },
    MethodSpec {
        name: "PHG/HSFC",
        in_lineup: true,
        description: "Hilbert SFC with PHG's aspect-preserving normalization (paper §2.2)",
        make: || Box::new(SfcPartitioner::phg_hsfc()),
    },
    MethodSpec {
        name: "Zoltan/HSFC",
        in_lineup: true,
        description: "Hilbert SFC with Zoltan's per-axis normalization (paper §2.2)",
        make: || Box::new(SfcPartitioner::zoltan_hsfc()),
    },
    MethodSpec {
        name: "Diffusion",
        in_lineup: false,
        description: "diffusive incremental repartitioning on the rank chain (DESIGN.md §7)",
        make: || Box::new(DiffusionRepartitioner::new()),
    },
    MethodSpec {
        name: "RIB",
        in_lineup: false,
        description: "recursive inertial bisection (geometric ablation baseline)",
        make: || Box::new(Rib::new()),
    },
    MethodSpec {
        name: "Mitchell-RT",
        in_lineup: false,
        description: "Mitchell's original refinement-tree bisection (§2.1 ablation)",
        make: || Box::new(MitchellRefinementTree::new()),
    },
];

/// Namespace for method lookup over [`METHODS`].
pub struct Registry;

impl Registry {
    /// Instantiate a method by its paper name. Unknown names error
    /// with the full list of valid ones.
    pub fn create(name: &str) -> Result<Box<dyn Partitioner>> {
        match METHODS.iter().find(|m| m.name == name) {
            Some(spec) => Ok((spec.make)()),
            None => bail!(
                "unknown method {name:?}; valid methods: {}",
                Self::names().join(", ")
            ),
        }
    }

    /// All registered method names, lineup first.
    pub fn names() -> Vec<&'static str> {
        METHODS.iter().map(|m| m.name).collect()
    }

    /// The paper's six-method lineup names, presentation order.
    pub fn paper_names() -> Vec<&'static str> {
        METHODS
            .iter()
            .filter(|m| m.in_lineup)
            .map(|m| m.name)
            .collect()
    }

    /// Instantiate the full paper lineup, presentation order.
    pub fn paper_lineup() -> Vec<Box<dyn Partitioner>> {
        METHODS
            .iter()
            .filter(|m| m.in_lineup)
            .map(|m| (m.make)())
            .collect()
    }

    /// Every spec in sorted (byte-order) name order: the deterministic
    /// listing that `phg-dlb methods` prints, so CI log diffs and docs
    /// stay stable across registry edits.
    pub fn sorted_specs() -> Vec<&'static MethodSpec> {
        let mut specs: Vec<&'static MethodSpec> = METHODS.iter().collect();
        specs.sort_by_key(|m| m.name);
        specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_knows_all_methods() {
        for spec in &METHODS {
            let p = Registry::create(spec.name).unwrap();
            assert_eq!(p.name(), spec.name, "registry name mismatch");
            assert!(!spec.description.is_empty(), "{} undescribed", spec.name);
        }
        assert!(Registry::create("RIB").is_ok());
        assert!(Registry::create("Mitchell-RT").is_ok());
        assert!(Registry::create("Diffusion").is_ok());
    }

    #[test]
    fn unknown_method_lists_valid_names() {
        let err = Registry::create("nope").unwrap_err().to_string();
        assert!(err.contains("nope"), "{err}");
        for name in Registry::names() {
            assert!(err.contains(name), "error does not list {name}: {err}");
        }
    }

    #[test]
    fn paper_lineup_has_six_methods_in_order() {
        assert_eq!(
            Registry::paper_names(),
            ["RCB", "ParMETIS", "RTK", "MSFC", "PHG/HSFC", "Zoltan/HSFC"]
        );
        let lineup = Registry::paper_lineup();
        assert_eq!(lineup.len(), 6);
        for (p, name) in lineup.iter().zip(Registry::paper_names()) {
            assert_eq!(p.name(), name);
        }
    }

    #[test]
    fn sorted_specs_are_sorted_and_complete() {
        let specs = Registry::sorted_specs();
        assert_eq!(specs.len(), METHODS.len());
        for w in specs.windows(2) {
            assert!(w[0].name < w[1].name, "{} !< {}", w[0].name, w[1].name);
        }
    }
}
