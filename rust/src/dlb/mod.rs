//! The dynamic load-balancing subsystem: *when* to rebalance
//! ([`TriggerPolicy`]), *what* load means ([`WeightModel`]), *which*
//! method runs ([`Registry`]), and *how* the pieces compose
//! ([`RebalancePipeline`]).
//!
//! The paper's core claim is that DLB quality comes from the whole
//! loop -- trigger policy, element weights, partitioning method and
//! the migration-minimizing remap together -- not from any single
//! phase. This module makes each of those a first-class, pluggable
//! part:
//!
//! * [`registry`] -- the one name -> partitioner table (replacing the
//!   three copies that used to disagree across the crate); specs are
//!   parameterizable as `name:key=val,...`, validated against each
//!   method's declared [`crate::partition::MethodTraits`];
//! * [`trigger`] -- lambda-threshold (the paper), fixed cadence, and
//!   cost/benefit policies priced against [`crate::dist::NetworkModel`];
//! * [`weights`] -- unit, dof-proportional, and runtime-measured
//!   element weight models;
//! * [`strategy`] -- scratch vs diffusive vs adaptive vs auto
//!   repartitioning ([`RepartitionStrategy`], DESIGN.md §7, §12);
//! * [`pipeline`] -- partition -> Oliker-Biswas remap -> migrate (or
//!   the remap-free diffusive/adaptive paths) as one call returning a
//!   structured [`RebalanceReport`].
//!
//! The adaptive driver ([`crate::coordinator`]), the CLI, the examples
//! and the benches all compose their DLB loops from these pieces.

pub mod pipeline;
pub mod registry;
pub mod strategy;
pub mod trigger;
pub mod weights;

pub use pipeline::{RebalancePipeline, RebalanceReport};
pub use registry::{MethodSpec, Registry, METHODS};
pub use strategy::RepartitionStrategy;
pub use trigger::{
    trigger_by_name, AfterAdaptation, CostBenefit, CostEstimate, LambdaThreshold, TriggerContext,
    TriggerPolicy, TriggerSpec, TRIGGERS,
};
pub use weights::{
    dof_shares, weight_model_by_name, DofWeighted, Measured, Unit, WeightModel, WeightSpec,
    WeightState, WEIGHT_MODELS,
};
