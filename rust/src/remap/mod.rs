//! Subgrid -> process mapping (§2.4): after partitioning, renumber the
//! new subgrids so they land on the processes already holding most of
//! their data, minimizing migration (TotalV).
//!
//! Oliker & Biswas (SPAA'97) heuristic: build the similarity matrix
//! S (p_old x p_new), S[i][j] = amount of data currently on rank i
//! that the new partition puts in subgrid j; process entries in
//! descending order, greedily locking (rank, subgrid) pairs; the
//! result maximizes F = sum_j S[map[j]][j] to within the heuristic's
//! known suboptimality bound.
//!
//! In PHG each rank computes one row of S concurrently, a master
//! gathers the matrix, solves the assignment, and broadcasts the
//! mapping -- we log exactly that collective pattern.

use crate::partition::CommOp;

/// Dense similarity matrix: `s[i][j]` = weight of data on old rank `i`
/// destined for new subgrid `j`.
#[derive(Debug, Clone)]
pub struct SimilarityMatrix {
    pub s: Vec<Vec<f64>>,
    pub p_old: usize,
    pub p_new: usize,
}

impl SimilarityMatrix {
    /// Build from per-leaf old owners, new parts and weights.
    pub fn build(owners: &[u16], parts: &[u16], weights: &[f64], p_old: usize, p_new: usize) -> Self {
        assert_eq!(owners.len(), parts.len());
        assert_eq!(owners.len(), weights.len());
        let mut s = vec![vec![0.0f64; p_new]; p_old];
        for i in 0..owners.len() {
            s[owners[i] as usize][parts[i] as usize] += weights[i];
        }
        Self { s, p_old, p_new }
    }

    /// Row sums = current per-rank data (sanity invariant).
    pub fn row_sums(&self) -> Vec<f64> {
        self.s.iter().map(|row| row.iter().sum()).collect()
    }

    /// The kept-data objective F for a given mapping
    /// (`map[j]` = rank that new subgrid j is assigned to).
    pub fn kept(&self, map: &[u16]) -> f64 {
        map.iter()
            .enumerate()
            .map(|(j, &r)| {
                if (r as usize) < self.p_old {
                    self.s[r as usize][j]
                } else {
                    0.0
                }
            })
            .sum()
    }
}

/// Result of the remapping step.
#[derive(Debug, Clone)]
pub struct RemapResult {
    /// `map[j]` = process that new subgrid `j` should live on.
    pub map: Vec<u16>,
    /// F = total data weight kept in place by this mapping.
    pub kept: f64,
    /// F for the identity mapping (what you'd get without remapping).
    pub kept_identity: f64,
    pub comm: Vec<CommOp>,
}

/// Oliker-Biswas greedy assignment.
pub fn oliker_biswas(sim: &SimilarityMatrix) -> RemapResult {
    let p_old = sim.p_old;
    let p_new = sim.p_new;

    // flatten + sort entries by weight descending
    let mut entries: Vec<(f64, u16, u16)> = Vec::with_capacity(p_old * p_new);
    for (i, row) in sim.s.iter().enumerate() {
        for (j, &w) in row.iter().enumerate() {
            if w > 0.0 {
                entries.push((w, i as u16, j as u16));
            }
        }
    }
    entries.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));

    let mut rank_taken = vec![false; p_old.max(p_new)];
    let mut map = vec![u16::MAX; p_new];
    let mut assigned = 0;
    for (_, i, j) in entries {
        if map[j as usize] == u16::MAX && !rank_taken[i as usize] {
            map[j as usize] = i;
            rank_taken[i as usize] = true;
            assigned += 1;
            if assigned == p_new.min(p_old) {
                break;
            }
        }
    }
    // leftovers (zero-similarity subgrids / fresh ranks): fill in order
    let mut free_ranks = (0..rank_taken.len() as u16).filter(|&r| !rank_taken[r as usize]);
    for slot in map.iter_mut() {
        if *slot == u16::MAX {
            *slot = free_ranks.next().expect("not enough ranks for subgrids");
        }
    }

    let mut kept = sim.kept(&map);
    let identity: Vec<u16> = (0..p_new as u16).collect();
    let kept_identity = sim.kept(&identity);
    // The greedy heuristic is 1/2-approximate; on adversarial
    // instances it can fall below the identity mapping. Since the
    // whole point (§2.4) is minimizing migration, never return a map
    // worse than doing nothing.
    if p_old == p_new && kept_identity > kept {
        map = identity.clone();
        kept = kept_identity;
    }

    // collectives: gather rows to master, broadcast the mapping
    let comm = vec![
        CommOp::Gather {
            bytes: p_old * p_new * 8,
        },
        CommOp::Bcast { bytes: p_new * 2 },
    ];
    RemapResult {
        map,
        kept,
        kept_identity,
        comm,
    }
}

/// Relabel new parts through the remapping: part j becomes map[j].
pub fn apply_map(parts: &mut [u16], map: &[u16]) {
    for p in parts.iter_mut() {
        *p = map[*p as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn similarity_rows_sum_to_rank_data() {
        let owners = vec![0u16, 0, 1, 1, 2];
        let parts = vec![1u16, 1, 0, 2, 2];
        let weights = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let sim = SimilarityMatrix::build(&owners, &parts, &weights, 3, 3);
        assert_eq!(sim.row_sums(), vec![3.0, 7.0, 5.0]);
        assert_eq!(sim.s[0][1], 3.0);
        assert_eq!(sim.s[1][0], 3.0);
        assert_eq!(sim.s[1][2], 4.0);
        assert_eq!(sim.s[2][2], 5.0);
    }

    #[test]
    fn identity_when_parts_unchanged() {
        // partition == current distribution: remap must keep everything
        let owners = vec![0u16, 1, 2, 0, 1, 2];
        let parts = owners.clone();
        let weights = vec![1.0; 6];
        let sim = SimilarityMatrix::build(&owners, &parts, &weights, 3, 3);
        let r = oliker_biswas(&sim);
        assert_eq!(r.map, vec![0, 1, 2]);
        assert_eq!(r.kept, 6.0);
        assert_eq!(r.kept, r.kept_identity);
    }

    #[test]
    fn permuted_parts_get_unpermuted() {
        // new partition is a pure relabeling 0->1->2->0 of the old:
        // remapping must undo it, keeping all data in place
        let owners = vec![0u16, 0, 1, 1, 2, 2];
        let parts = vec![1u16, 1, 2, 2, 0, 0];
        let weights = vec![1.0; 6];
        let sim = SimilarityMatrix::build(&owners, &parts, &weights, 3, 3);
        let r = oliker_biswas(&sim);
        // subgrid 1 lives on rank 0, subgrid 2 on rank 1, subgrid 0 on rank 2
        assert_eq!(r.map, vec![2, 0, 1]);
        assert_eq!(r.kept, 6.0);
        assert!(r.kept_identity < 1e-12);

        let mut p = parts.clone();
        apply_map(&mut p, &r.map);
        assert_eq!(p, owners);
    }

    #[test]
    fn map_is_a_permutation() {
        propcheck::check("oliker-biswas yields a permutation", |rng| {
            let p = 2 + rng.gen_range(12);
            let n = 50 + rng.gen_range(200);
            let owners: Vec<u16> = (0..n).map(|_| rng.gen_range(p) as u16).collect();
            let parts: Vec<u16> = (0..n).map(|_| rng.gen_range(p) as u16).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.gen_uniform(0.1, 3.0)).collect();
            let sim = SimilarityMatrix::build(&owners, &parts, &weights, p, p);
            let r = oliker_biswas(&sim);
            let mut seen = vec![false; p];
            for &m in &r.map {
                assert!((m as usize) < p);
                assert!(!seen[m as usize], "rank {m} assigned twice");
                seen[m as usize] = true;
            }
        });
    }

    #[test]
    fn never_worse_than_identity() {
        propcheck::check("remap kept >= identity kept", |rng| {
            let p = 2 + rng.gen_range(10);
            let n = 50 + rng.gen_range(300);
            let owners: Vec<u16> = (0..n).map(|_| rng.gen_range(p) as u16).collect();
            let parts: Vec<u16> = (0..n).map(|_| rng.gen_range(p) as u16).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.gen_uniform(0.1, 2.0)).collect();
            let sim = SimilarityMatrix::build(&owners, &parts, &weights, p, p);
            let r = oliker_biswas(&sim);
            assert!(
                r.kept >= r.kept_identity - 1e-9,
                "kept {} < identity {}",
                r.kept,
                r.kept_identity
            );
        });
    }

    #[test]
    fn greedy_achieves_half_of_optimum_bound() {
        // the greedy heuristic is 1/2-approximate for this assignment
        // objective; verify against brute force on small instances
        propcheck::check_with(7, 24, "greedy >= 1/2 optimal", |rng| {
            let p = 2 + rng.gen_range(4); // up to 5 -> brute force 120 perms
            let mut s = vec![vec![0.0f64; p]; p];
            for row in s.iter_mut() {
                for v in row.iter_mut() {
                    *v = rng.gen_uniform(0.0, 10.0);
                }
            }
            let sim = SimilarityMatrix {
                s,
                p_old: p,
                p_new: p,
            };
            let r = oliker_biswas(&sim);
            // brute force optimum
            let mut perm: Vec<u16> = (0..p as u16).collect();
            let mut best = 0.0f64;
            permute(&mut perm, 0, &mut |pm| {
                best = best.max(sim.kept(pm));
            });
            assert!(
                r.kept >= 0.5 * best - 1e-9,
                "greedy {} vs opt {}",
                r.kept,
                best
            );
        });
    }

    fn permute(v: &mut Vec<u16>, k: usize, f: &mut impl FnMut(&[u16])) {
        if k == v.len() {
            f(v);
            return;
        }
        for i in k..v.len() {
            v.swap(k, i);
            permute(v, k + 1, f);
            v.swap(k, i);
        }
    }

    #[test]
    fn rectangular_more_ranks_than_subgrids() {
        let owners = vec![0u16, 1, 2, 3];
        let parts = vec![0u16, 0, 1, 1];
        let weights = vec![1.0; 4];
        let sim = SimilarityMatrix::build(&owners, &parts, &weights, 4, 2);
        let r = oliker_biswas(&sim);
        assert_eq!(r.map.len(), 2);
        assert_ne!(r.map[0], r.map[1]);
    }
}
