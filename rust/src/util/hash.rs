//! FxHash-style fast hasher for the mesh's edge/face maps.
//!
//! The refinement closure and topology builds hash millions of packed
//! edge/face keys per adapt step; std's SipHash is a measurable drag
//! there (it shows up in the §Perf profile), and we need no DoS
//! resistance for internal integer keys.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher (the rustc FxHasher recipe, u64 flavour).
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = HashSet<K, FxBuildHasher>;

/// Pack an (unordered) vertex pair into a sorted u64 edge key.
#[inline]
pub fn edge_key(a: u32, b: u32) -> u64 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    ((lo as u64) << 32) | hi as u64
}

/// Pack an (unordered) vertex triple into a sorted u128 face key.
#[inline]
pub fn face_key(a: u32, b: u32, c: u32) -> u128 {
    let mut v = [a, b, c];
    v.sort_unstable();
    ((v[0] as u128) << 64) | ((v[1] as u128) << 32) | v[2] as u128
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_key_symmetric() {
        assert_eq!(edge_key(3, 9), edge_key(9, 3));
        assert_ne!(edge_key(3, 9), edge_key(3, 10));
    }

    #[test]
    fn face_key_order_invariant() {
        let k = face_key(5, 1, 9);
        assert_eq!(k, face_key(9, 5, 1));
        assert_eq!(k, face_key(1, 9, 5));
        assert_ne!(k, face_key(1, 9, 6));
    }

    #[test]
    fn fxmap_works() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert(edge_key(i, i + 1), i);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&edge_key(43, 42)], 42);
    }

    #[test]
    fn hasher_distributes() {
        // weak sanity: different keys rarely collide in low bits
        let mut buckets = [0u32; 64];
        for i in 0..6400u64 {
            let mut h = FxHasher::default();
            h.write_u64(i);
            buckets[(h.finish() & 63) as usize] += 1;
        }
        let max = *buckets.iter().max().unwrap();
        assert!(max < 300, "max bucket {max}");
    }
}
