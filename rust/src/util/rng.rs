//! Deterministic PRNGs for tests, benchmarks and synthetic workloads.
//!
//! No external `rand` crate is vendored in this environment, so we carry
//! our own: SplitMix64 for seeding and a PCG-XSH-RR 64/32 generator for
//! the streams. Both are tiny, well-studied and fully deterministic
//! across platforms, which matters for reproducible experiments.

/// SplitMix64: used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: the workhorse generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut rng = Self { state, inc };
        rng.next_u32(); // warm up
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64() >> 11; // 53 bits
            let limit = (u64::MAX >> 11) - ((u64::MAX >> 11) % bound + 1) % bound;
            if x <= limit {
                return (x % bound) as usize;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Standard normal via Box-Muller (one value per call; cheap enough).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// `true` with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_bounds_respected() {
        let mut rng = Pcg32::new(7);
        for bound in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(rng.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(9);
        for _ in 0..1000 {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments_reasonable() {
        let mut rng = Pcg32::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
