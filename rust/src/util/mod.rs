//! Foundation utilities: deterministic PRNGs, timers, statistics, a
//! radix sort for SFC keys, a tiny property-testing harness, and the
//! crate's dependency-free error type.

pub mod error;
pub mod hash;
pub mod propcheck;
pub mod rng;
pub mod sort;
pub mod stats;
pub mod timer;
