//! Foundation utilities: deterministic PRNGs, timers, statistics, a
//! radix sort for SFC keys, and a tiny property-testing harness.

pub mod hash;
pub mod propcheck;
pub mod rng;
pub mod sort;
pub mod stats;
pub mod timer;
