//! Minimal property-testing harness (proptest is not vendored in this
//! environment). A property is a closure over a seeded `Pcg32`; the
//! harness runs it across many derived seeds and reports the failing
//! seed so a failure is reproducible with `PROPCHECK_SEED=<n>`.

use super::rng::{Pcg32, SplitMix64};

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` for `cases` seeds derived from `base_seed`. Panics with
/// the failing case's seed on the first failure.
pub fn check_with(base_seed: u64, cases: usize, name: &str, mut prop: impl FnMut(&mut Pcg32)) {
    let override_seed = std::env::var("PROPCHECK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let mut sm = SplitMix64::new(base_seed);
    for case in 0..cases {
        let seed = override_seed.unwrap_or_else(|| sm.next_u64());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg32::new(seed);
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n\
                 reproduce with PROPCHECK_SEED={seed}"
            );
        }
        if override_seed.is_some() {
            break;
        }
    }
}

/// Run `prop` with the default case count.
pub fn check(name: &str, prop: impl FnMut(&mut Pcg32)) {
    check_with(0x9E3779B97F4A7C15, DEFAULT_CASES, name, prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", |rng| {
            let a = rng.gen_f64();
            let b = rng.gen_f64();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn seeds_vary_between_cases() {
        let mut values = Vec::new();
        check_with(1, 8, "collect", |rng| values.push(rng.next_u64()));
        let mut uniq = values.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), values.len());
    }
}
