//! Small statistics helpers shared by metrics, benches and reports.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub std: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Self {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            std: var.sqrt(),
            median,
        }
    }
}

/// Load imbalance factor: max_i w_i / mean_i w_i. 1.0 is perfect.
/// This is the lambda the DLB policy triggers on.
pub fn imbalance(weights: &[f64]) -> f64 {
    if weights.is_empty() {
        return 1.0;
    }
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return 1.0;
    }
    let mean = total / weights.len() as f64;
    weights.iter().cloned().fold(0.0f64, f64::max) / mean
}

/// Coefficient of variation (std/mean) -- used to quantify the
/// "oscillation" of ParMETIS-style partition times in Fig 3.2.
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let s = Summary::of(xs);
    if s.mean == 0.0 {
        0.0
    } else {
        s.std / s.mean
    }
}

/// Linear-regression slope of y against x (least squares). Used by the
/// benches to report growth rates of partition time vs mesh size.
pub fn slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    if sxx == 0.0 {
        0.0
    } else {
        sxy / sxx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.median, 2.5);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_odd_median() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn imbalance_perfect() {
        assert_eq!(imbalance(&[2.0, 2.0, 2.0]), 1.0);
    }

    #[test]
    fn imbalance_detects_skew() {
        let l = imbalance(&[4.0, 1.0, 1.0]);
        assert!((l - 2.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_degenerate() {
        assert_eq!(imbalance(&[]), 1.0);
        assert_eq!(imbalance(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn slope_of_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        assert!((slope(&x, &y) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cv_zero_for_constant() {
        assert_eq!(coeff_of_variation(&[3.0, 3.0, 3.0]), 0.0);
    }
}
