//! Wall-clock timing helpers and phase accumulators used by the
//! coordinator's timeline and the benchmark harnesses.

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch returning seconds.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates wall time per named phase. The coordinator charges
/// phases like "partition", "migrate", "assemble", "solve" here and the
/// report module turns them into the paper's TAL/DLB/SOL/STP columns.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    totals: BTreeMap<String, f64>,
    counts: BTreeMap<String, u64>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, charging its wall time to `phase`.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        self.add(phase, sw.elapsed());
        out
    }

    pub fn add(&mut self, phase: &str, secs: f64) {
        *self.totals.entry(phase.to_string()).or_insert(0.0) += secs;
        *self.counts.entry(phase.to_string()).or_insert(0) += 1;
    }

    pub fn total(&self, phase: &str) -> f64 {
        self.totals.get(phase).copied().unwrap_or(0.0)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or(0)
    }

    pub fn mean(&self, phase: &str) -> f64 {
        let c = self.count(phase);
        if c == 0 {
            0.0
        } else {
            self.total(phase) / c as f64
        }
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, f64, u64)> {
        self.totals
            .iter()
            .map(move |(k, v)| (k.as_str(), *v, self.count(k)))
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_insert(0) += v;
        }
    }

    pub fn grand_total(&self) -> f64 {
        self.totals.values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed();
        let b = sw.elapsed();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.add("solve", 1.0);
        pt.add("solve", 2.0);
        pt.add("partition", 0.5);
        assert_eq!(pt.total("solve"), 3.0);
        assert_eq!(pt.count("solve"), 2);
        assert_eq!(pt.mean("solve"), 1.5);
        assert_eq!(pt.total("partition"), 0.5);
        assert_eq!(pt.total("absent"), 0.0);
        assert!((pt.grand_total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut pt = PhaseTimer::new();
        let v = pt.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(pt.count("work"), 1);
        assert!(pt.total("work") >= 0.0);
    }

    #[test]
    fn merge_sums_phases() {
        let mut a = PhaseTimer::new();
        a.add("x", 1.0);
        let mut b = PhaseTimer::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert_eq!(a.total("x"), 3.0);
        assert_eq!(a.total("y"), 3.0);
        assert_eq!(a.count("x"), 2);
    }
}
