//! LSD radix sort for `(u64 key, u32 payload)` pairs.
//!
//! The SFC partitioners sort millions of (Hilbert/Morton key, element)
//! pairs per repartition; this is their dominant cost and the first
//! target of the performance pass. An 8-bit-digit LSD radix sort is
//! ~3-5x faster than comparison sort at these sizes and is stable,
//! which keeps the partition deterministic under ties.

/// Sort `items` by key ascending, stable. Allocates one scratch buffer.
pub fn radix_sort_by_key(items: &mut Vec<(u64, u32)>) {
    let n = items.len();
    if n <= 64 {
        items.sort_by_key(|&(k, _)| k);
        return;
    }
    // Skip passes whose digit is constant (common: high bytes all zero).
    let mut or_all = 0u64;
    let mut and_all = u64::MAX;
    for &(k, _) in items.iter() {
        or_all |= k;
        and_all &= k;
    }
    let mut scratch: Vec<(u64, u32)> = Vec::with_capacity(n);
    #[allow(clippy::uninit_vec)]
    unsafe {
        scratch.set_len(n);
    }
    let mut src_is_items = true;
    for pass in 0..8 {
        let shift = pass * 8;
        let or_d = ((or_all >> shift) & 0xFF) as u8;
        let and_d = ((and_all >> shift) & 0xFF) as u8;
        if or_d == and_d {
            continue; // all keys share this digit; pass is a no-op
        }
        let (src, dst): (&mut [(u64, u32)], &mut [(u64, u32)]) = if src_is_items {
            (&mut items[..], &mut scratch[..])
        } else {
            (&mut scratch[..], &mut items[..])
        };
        let mut counts = [0usize; 256];
        for &(k, _) in src.iter() {
            counts[((k >> shift) & 0xFF) as usize] += 1;
        }
        let mut offsets = [0usize; 256];
        let mut acc = 0;
        for d in 0..256 {
            offsets[d] = acc;
            acc += counts[d];
        }
        for &(k, p) in src.iter() {
            let d = ((k >> shift) & 0xFF) as usize;
            dst[offsets[d]] = (k, p);
            offsets[d] += 1;
        }
        src_is_items = !src_is_items;
    }
    if !src_is_items {
        items.copy_from_slice(&scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn sorts_small() {
        let mut v = vec![(3u64, 0u32), (1, 1), (2, 2)];
        radix_sort_by_key(&mut v);
        assert_eq!(v, vec![(1, 1), (2, 2), (3, 0)]);
    }

    #[test]
    fn sorts_empty_and_single() {
        let mut v: Vec<(u64, u32)> = vec![];
        radix_sort_by_key(&mut v);
        assert!(v.is_empty());
        let mut v = vec![(9u64, 7u32)];
        radix_sort_by_key(&mut v);
        assert_eq!(v, vec![(9, 7)]);
    }

    #[test]
    fn stable_on_ties() {
        let mut v: Vec<(u64, u32)> = (0..1000).map(|i| ((i % 7) as u64, i as u32)).collect();
        radix_sort_by_key(&mut v);
        for w in v.windows(2) {
            assert!(w[0].0 <= w[1].0);
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stability violated");
            }
        }
    }

    #[test]
    fn matches_std_sort_property() {
        propcheck::check("radix == std sort", |rng| {
            let n = rng.gen_range(5000) + 1;
            let mut v: Vec<(u64, u32)> = (0..n)
                .map(|i| {
                    // mix of full-range and low-range keys to exercise
                    // the pass-skipping fast path
                    let k = if rng.gen_bool(0.5) {
                        rng.next_u64()
                    } else {
                        rng.next_u64() & 0xFFFF
                    };
                    (k, i as u32)
                })
                .collect();
            let mut expect = v.clone();
            expect.sort_by_key(|&(k, _)| k);
            radix_sort_by_key(&mut v);
            assert_eq!(v.iter().map(|x| x.0).collect::<Vec<_>>(),
                       expect.iter().map(|x| x.0).collect::<Vec<_>>());
        });
    }
}
