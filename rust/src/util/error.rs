//! Minimal error handling for the crate: a message-carrying error
//! type, a `Result` alias, a `Context` extension trait, and the
//! [`format_err!`]/[`bail!`] macros.
//!
//! This replaces the crate's earlier `anyhow` dependency. The build
//! environment has no crates.io access, so the crate must be hermetic:
//! zero external dependencies, a trivially-correct committed
//! `Cargo.lock`, and a CI build that never touches the network.
//! Errors here are plain formatted messages -- exactly how the crate
//! used `anyhow` -- so nothing is lost at the call sites.
//!
//! [`format_err!`]: crate::format_err
//! [`bail!`]: crate::bail

use std::fmt;

/// A string-message error. Construct with [`Error::msg`] or the
/// [`crate::format_err!`] macro; chain context with [`Context`].
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug prints the message (not a struct dump) so `unwrap()`/`expect()`
// panics and `{e:?}` logs stay readable, as they were under anyhow.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self::msg(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Self::msg(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Self::msg(e.to_string())
    }
}

/// Crate-wide result type (error defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach a message in front of an underlying error, `anyhow`-style.
pub trait Context<T> {
    fn context(self, msg: impl Into<String>) -> Result<T>;
    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", msg.into())))
    }

    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f().into())))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::format_err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("bad {} of {}", "state", 42)
    }

    #[test]
    fn display_and_debug_show_the_message() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad state of 42");
        assert_eq!(format!("{e:?}"), "bad state of 42");
        assert_eq!(format!("{e:#}"), "bad state of 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.with_context(|| "reading manifest".to_string()).unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("reading manifest: "), "{s}");
        assert!(s.contains("gone"), "{s}");
    }
}
