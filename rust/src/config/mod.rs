//! Configuration: a tiny `key = value` file format (TOML subset --
//! no external crates in this environment) plus command-line
//! `--key value` overrides. The launcher (`main.rs`) and the benches
//! build [`crate::coordinator::DriverConfig`]s from this.

use crate::format_err;
use crate::util::error::Result;
use std::collections::BTreeMap;

/// Parsed configuration: flat string map with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse `key = value` lines; `#` comments; blank lines ignored.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format_err!("line {}: expected key = value", lineno + 1))?;
            values.insert(
                k.trim().to_string(),
                v.trim().trim_matches('"').to_string(),
            );
        }
        Ok(Self { values })
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Apply `--key value` style overrides (leading dashes stripped).
    pub fn apply_args(&mut self, args: &[String]) -> Result<Vec<String>> {
        let mut rest = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let v = it
                    .next()
                    .ok_or_else(|| format_err!("missing value for --{key}"))?;
                self.values.insert(key.replace('-', "_"), v.clone());
            } else {
                rest.push(a.clone());
            }
        }
        Ok(rest)
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Apply pre-split `(key, value)` overrides in order (later pairs
    /// win). The serve job model stores its `DriverConfig` overrides
    /// this way (`serve::JobSpec::overrides`).
    pub fn apply_pairs<K: AsRef<str>, V: ToString>(&mut self, pairs: &[(K, V)]) {
        for (k, v) in pairs {
            self.set(k.as_ref(), v.to_string());
        }
    }

    /// Whether the key was given (file or CLI), as opposed to an
    /// accessor falling back to its default.
    pub fn contains(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.values
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format_err!("config {key} = {v}: expected integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format_err!("config {key} = {v}: expected float")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(format_err!("config {key} = {v}: expected bool")),
        }
    }

    /// Build a DriverConfig with config-file defaults + overrides.
    pub fn driver_config(&self) -> Result<crate::coordinator::DriverConfig> {
        use crate::fem::SolverOpts;
        Ok(crate::coordinator::DriverConfig {
            problem: self.get_str("problem", "helmholtz"),
            nparts: self.get_usize("nparts", 16)?,
            method: self.get_str("method", "PHG/HSFC"),
            trigger: self.get_str("trigger", "lambda"),
            weights: self.get_str("weights", "unit"),
            strategy: self.get_str("strategy", "scratch"),
            exec: self.get_str("exec", "virtual"),
            exec_threads: self.get_usize("exec_threads", 0)?,
            lambda_trigger: self.get_f64("lambda_trigger", 1.2)?,
            theta_refine: self.get_f64("theta_refine", 0.5)?,
            theta_coarsen: self.get_f64("theta_coarsen", 0.0)?,
            max_elements: self.get_usize("max_elements", 200_000)?,
            solver: SolverOpts {
                tol: self.get_f64("solver_tol", 1e-6)?,
                max_iter: self.get_usize("solver_max_iter", 2000)?,
            },
            // default build: only the always-erroring stub exists, so
            // constructing a PJRT client would be a pure error path
            use_pjrt: self.get_bool("use_pjrt", cfg!(feature = "pjrt"))?,
            nsteps: self.get_usize("nsteps", 10)?,
            dt: self.get_f64("dt", 1e-3)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        let c = Config::parse(
            "# scenario\nnparts = 32\nmethod = \"RTK\"\nlambda_trigger = 1.3\nuse_pjrt = false\n",
        )
        .unwrap();
        assert_eq!(c.get_usize("nparts", 0).unwrap(), 32);
        assert_eq!(c.get_str("method", ""), "RTK");
        assert_eq!(c.get_f64("lambda_trigger", 0.0).unwrap(), 1.3);
        assert!(!c.get_bool("use_pjrt", true).unwrap());
    }

    #[test]
    fn defaults_on_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_usize("absent", 7).unwrap(), 7);
        assert_eq!(c.get_str("absent", "x"), "x");
    }

    #[test]
    fn rejects_bad_lines_and_types() {
        assert!(Config::parse("no_equals_here\n").is_err());
        let c = Config::parse("nparts = banana\n").unwrap();
        assert!(c.get_usize("nparts", 1).is_err());
        let c = Config::parse("flag = maybe\n").unwrap();
        assert!(c.get_bool("flag", true).is_err());
    }

    #[test]
    fn args_override_and_passthrough() {
        let mut c = Config::parse("nparts = 8\n").unwrap();
        let rest = c
            .apply_args(&[
                "run".to_string(),
                "--nparts".to_string(),
                "64".to_string(),
                "--method".to_string(),
                "RCB".to_string(),
            ])
            .unwrap();
        assert_eq!(rest, vec!["run"]);
        assert_eq!(c.get_usize("nparts", 0).unwrap(), 64);
        assert_eq!(c.get_str("method", ""), "RCB");
    }

    #[test]
    fn apply_pairs_layers_job_overrides() {
        // the serve path: JSONL overrides -> Config -> DriverConfig
        let mut c = Config::new();
        c.apply_pairs(&[("problem", "parabolic"), ("nparts", "8"), ("nparts", "4")]);
        c.set("nsteps", 3usize);
        let dc = c.driver_config().unwrap();
        assert_eq!(dc.problem, "parabolic");
        assert_eq!(dc.nparts, 4, "later pairs win");
        assert_eq!(dc.nsteps, 3);
    }

    #[test]
    fn dashes_normalize_to_underscores() {
        let mut c = Config::new();
        c.apply_args(&["--lambda-trigger".into(), "1.5".into()])
            .unwrap();
        assert_eq!(c.get_f64("lambda_trigger", 0.0).unwrap(), 1.5);
    }

    #[test]
    fn driver_config_roundtrip() {
        let c = Config::parse("nparts = 12\nmethod = RCB\nnsteps = 5\n").unwrap();
        let d = c.driver_config().unwrap();
        assert_eq!(d.nparts, 12);
        assert_eq!(d.method, "RCB");
        assert_eq!(d.nsteps, 5);
        assert_eq!(d.lambda_trigger, 1.2); // default
        assert_eq!(d.trigger, "lambda"); // default
        assert_eq!(d.weights, "unit"); // default
        assert_eq!(d.strategy, "scratch"); // default
        assert_eq!(d.problem, "helmholtz"); // default
        // PJRT only engages when the feature (and so a real client)
        // is compiled in
        assert_eq!(d.use_pjrt, cfg!(feature = "pjrt"));
    }

    #[test]
    fn problem_key_flows_through() {
        let mut c = Config::parse("problem = lshape\n").unwrap();
        assert_eq!(c.driver_config().unwrap().problem, "lshape");
        c.apply_args(&["--problem".into(), "oscillator".into()])
            .unwrap();
        assert_eq!(c.driver_config().unwrap().problem, "oscillator");
    }

    #[test]
    fn trigger_weights_and_strategy_keys_flow_through() {
        let mut c = Config::parse("trigger = costbenefit:4\nstrategy = auto\n").unwrap();
        c.apply_args(&["--weights".into(), "measured".into()]).unwrap();
        let d = c.driver_config().unwrap();
        assert_eq!(d.trigger, "costbenefit:4");
        assert_eq!(d.weights, "measured");
        assert_eq!(d.strategy, "auto");
        let mut c = Config::new();
        c.apply_args(&["--strategy".into(), "diffusive".into()]).unwrap();
        assert_eq!(c.driver_config().unwrap().strategy, "diffusive");
        let mut c = Config::new();
        c.apply_args(&["--strategy".into(), "adaptive".into()]).unwrap();
        assert_eq!(c.driver_config().unwrap().strategy, "adaptive");
    }

    #[test]
    fn parameterized_method_specs_flow_through_verbatim() {
        // `name:key=val,...` specs are opaque strings to the config
        // layer; the registry parses and validates them at creation
        let c = Config::parse("method = AdaptiveRepart:itr=100,fm_passes=8\n").unwrap();
        let d = c.driver_config().unwrap();
        assert_eq!(d.method, "AdaptiveRepart:itr=100,fm_passes=8");
        let mut c = Config::new();
        c.apply_args(&["--method".into(), "Diffusion:max_sweeps=16".into()])
            .unwrap();
        assert_eq!(c.driver_config().unwrap().method, "Diffusion:max_sweeps=16");
    }

    #[test]
    fn exec_keys_flow_through() {
        let c = Config::parse("").unwrap();
        let d = c.driver_config().unwrap();
        assert_eq!(d.exec, "virtual"); // default
        assert_eq!(d.exec_threads, 0); // default: auto

        let mut c = Config::parse("exec = threads\n").unwrap();
        c.apply_args(&["--exec-threads".into(), "4".into()]).unwrap();
        let d = c.driver_config().unwrap();
        assert_eq!(d.exec, "threads");
        assert_eq!(d.exec_threads, 4);
    }

    #[test]
    fn obs_keys_flow_through() {
        // --trace / --metrics are plain string keys: empty = off
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_str("trace", ""), "");
        assert_eq!(c.get_str("metrics", ""), "");

        let mut c = Config::parse("").unwrap();
        let args = [
            "--trace".to_string(),
            "out/run.json".to_string(),
            "--metrics".to_string(),
            "-".to_string(),
        ];
        c.apply_args(&args).unwrap();
        assert_eq!(c.get_str("trace", ""), "out/run.json");
        assert_eq!(c.get_str("metrics", ""), "-");
    }

    #[test]
    fn status_plane_and_flight_keys_flow_through() {
        // --status-port / --flight / and the `top` client's
        // --connect/--interval/--polls are plain flat keys too: no
        // schema change was needed to add the status plane
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_usize("status_port", 0).unwrap(), 0); // off
        assert_eq!(c.get_str("flight", ""), "");

        let mut c = Config::parse("status_port = 8080\n").unwrap();
        assert_eq!(c.get_usize("status_port", 0).unwrap(), 8080);
        c.apply_args(&[
            "--status-port".into(),
            "9100".into(),
            "--flight".into(),
            "out/flight.jsonl".into(),
        ])
        .unwrap();
        assert_eq!(c.get_usize("status_port", 0).unwrap(), 9100);
        assert_eq!(c.get_str("flight", ""), "out/flight.jsonl");

        let mut c = Config::new();
        c.apply_args(&[
            "--connect".into(),
            "127.0.0.1:9100".into(),
            "--interval".into(),
            "0.5".into(),
            "--polls".into(),
            "3".into(),
        ])
        .unwrap();
        assert_eq!(c.get_str("connect", ""), "127.0.0.1:9100");
        assert_eq!(c.get_f64("interval", 1.0).unwrap(), 0.5);
        assert_eq!(c.get_usize("polls", 0).unwrap(), 3);
    }
}
